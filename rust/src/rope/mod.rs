//! RoPE machinery: pairing strategies, theta tables, and three application
//! strategies mirroring the paper's §4.5 kernel comparison:
//!
//! * `apply_full`          — contiguous baseline (one shared theta table).
//! * `apply_gather`        — "PyTorch"-style: materialise full cos/sin for
//!   the position range, then index per-head retained columns (allocates the
//!   gathered tables — the "fake overhead" the paper calls out).
//! * `RopeTable::apply_fused` — the RAP hot path: per-head theta tables for
//!   exactly the retained pairs precomputed once at plan time; rotation
//!   reads them directly with zero per-call allocation.
//!
//! Latent tensors use the canonical half layout `[a_0..a_{m-1}, b_0..b_m]`
//! (see python/compile/kernels/ref.py — layouts must match bit-for-bit for
//! cache interchange between PJRT and the Rust engine).

use crate::config::{ModelConfig, Pairing};

/// Angular frequency of RoPE pair `j`: base^(-2j / D).
pub fn theta(j: usize, head_dim: usize, base: f64) -> f64 {
    base.powf(-2.0 * j as f64 / head_dim as f64)
}

/// Full per-pair frequency table [n_pairs].
pub fn theta_table(head_dim: usize, base: f64) -> Vec<f64> {
    (0..head_dim / 2).map(|j| theta(j, head_dim, base)).collect()
}

/// Standard RoPE on a full-width head vector, in place.
/// `x`: one head row of length D at position `pos`.
pub fn apply_full(x: &mut [f32], pos: usize, pairing: Pairing, base: f64) {
    let d = x.len();
    let p = d / 2;
    for j in 0..p {
        let (a_idx, b_idx) = pairing.pair_cols(j, d);
        let ang = pos as f64 * theta(j, d, base);
        let (sin, cos) = ang.sin_cos();
        let (a, b) = (x[a_idx] as f64, x[b_idx] as f64);
        x[a_idx] = (a * cos - b * sin) as f32;
        x[b_idx] = (a * sin + b * cos) as f32;
    }
}

/// `apply_full` over a token-major chunk: `x` holds one row of
/// `heads * head_width` floats per token, token `s` sits at position
/// `pos0 + s`, and every head row of that token is rotated at that
/// position.  This is the chunked-prefill form — one call rotates a whole
/// prompt chunk in place with per-row arithmetic identical to the token
/// loop's `apply_full` calls.
pub fn apply_full_tokens(
    x: &mut [f32],
    heads: usize,
    head_width: usize,
    pos0: usize,
    pairing: Pairing,
    base: f64,
) {
    for (s, tok) in x.chunks_mut(heads * head_width).enumerate() {
        for row in tok.chunks_mut(head_width) {
            apply_full(row, pos0 + s, pairing, base);
        }
    }
}

/// The materialising-gather variant: builds cos/sin tables for the retained
/// pairs of one head (freshly allocated per call — deliberately reproducing
/// the PyTorch indexing cost model), then rotates.
/// `x`: latent row [2m] in half layout; `pair_idx`: retained pair indices.
pub fn apply_gather(
    x: &mut [f32],
    pos: usize,
    pair_idx: &[usize],
    head_dim: usize,
    base: f64,
) {
    let m = pair_idx.len();
    debug_assert_eq!(x.len(), 2 * m);
    // Step 1: full tables (what a framework broadcast would have cached).
    let full: Vec<(f32, f32)> = (0..head_dim / 2)
        .map(|j| {
            let ang = pos as f64 * theta(j, head_dim, base);
            let (s, c) = ang.sin_cos();
            (c as f32, s as f32)
        })
        .collect();
    // Step 2: materialising gather into new buffers (the extra copies).
    let cos: Vec<f32> = pair_idx.iter().map(|&j| full[j].0).collect();
    let sin: Vec<f32> = pair_idx.iter().map(|&j| full[j].1).collect();
    // Step 3: rotate.
    for i in 0..m {
        let (a, b) = (x[i], x[m + i]);
        x[i] = a * cos[i] - b * sin[i];
        x[m + i] = a * sin[i] + b * cos[i];
    }
}

/// Precomputed per-head retained-pair frequency table — the fused hot path.
///
/// Built once when a pruning plan is loaded; `apply_fused` then performs the
/// rotation with no table construction, no gather, no allocation.  This is
/// the Rust analog of the paper's Triton kernel (and of our Pallas kernel's
/// VMEM-resident `theta_sel`).
#[derive(Debug, Clone)]
pub struct RopeTable {
    /// [n_heads][m] frequencies of the retained pairs.
    pub theta_sel: Vec<Vec<f32>>,
    pub m: usize,
}

impl RopeTable {
    /// Build from retained pair indices `[n_heads][m]`.
    pub fn new(pair_idx: &[Vec<usize>], head_dim: usize, base: f64) -> RopeTable {
        let m = pair_idx.first().map(|v| v.len()).unwrap_or(0);
        let theta_sel = pair_idx
            .iter()
            .map(|idx| {
                debug_assert_eq!(idx.len(), m, "head-uniform m required (paper §4.2)");
                idx.iter()
                    .map(|&j| theta(j, head_dim, base) as f32)
                    .collect()
            })
            .collect();
        RopeTable { theta_sel, m }
    }

    /// Full (no pruning) table for a baseline head in half layout.
    pub fn full(cfg: &ModelConfig) -> RopeTable {
        let idx: Vec<Vec<usize>> = vec![(0..cfg.n_pairs()).collect(); cfg.n_kv_heads];
        RopeTable::new(&idx, cfg.head_dim, cfg.rope_theta)
    }

    /// Rotate one latent head row [2m] (half layout) at `pos`, in place.
    #[inline]
    pub fn apply_fused(&self, head: usize, x: &mut [f32], pos: usize) {
        let m = self.m;
        debug_assert_eq!(x.len(), 2 * m);
        let thetas = &self.theta_sel[head];
        let posf = pos as f32;
        let (lo, hi) = x.split_at_mut(m);
        for i in 0..m {
            // sin/cos in f32: the angle magnitude is bounded by pos * theta_0
            // < max_seq, well inside f32's exact-integer range.
            let ang = posf * thetas[i];
            let (sin, cos) = ang.sin_cos();
            let (a, b) = (lo[i], hi[i]);
            lo[i] = a * cos - b * sin;
            hi[i] = a * sin + b * cos;
        }
    }

    /// Rotate a [S, 2m] latent block whose row s is at position pos0 + s.
    pub fn apply_fused_block(&self, head: usize, x: &mut [f32], pos0: usize) {
        let w = 2 * self.m;
        for (s, row) in x.chunks_mut(w).enumerate() {
            self.apply_fused(head, row, pos0 + s);
        }
    }

    /// Rotate a token-major [S, heads*2m] chunk in place: token `s` (at
    /// position `pos0 + s`) holds `heads` contiguous latent head rows, each
    /// rotated with its own per-head theta table — the chunked-prefill
    /// counterpart of per-token `apply_fused` calls (same per-row
    /// arithmetic, one call per chunk).
    pub fn apply_fused_chunk(&self, x: &mut [f32], heads: usize, pos0: usize) {
        let w = 2 * self.m;
        for (s, tok) in x.chunks_mut(heads * w).enumerate() {
            for (h, row) in tok.chunks_mut(w).enumerate() {
                self.apply_fused(h, row, pos0 + s);
            }
        }
    }
}

/// Convert a full-width head row from the model's native pairing into the
/// canonical half layout (used when cross-checking baseline caches).
pub fn to_half_layout(x: &[f32], pairing: Pairing) -> Vec<f32> {
    let d = x.len();
    let p = d / 2;
    let mut out = vec![0.0f32; d];
    for j in 0..p {
        let (a, b) = pairing.pair_cols(j, d);
        out[j] = x[a];
        out[p + j] = x[b];
    }
    out
}

/// Inverse of `to_half_layout`.
pub fn from_half_layout(x: &[f32], pairing: Pairing) -> Vec<f32> {
    let d = x.len();
    let p = d / 2;
    let mut out = vec![0.0f32; d];
    for j in 0..p {
        let (a, b) = pairing.pair_cols(j, d);
        out[a] = x[j];
        out[b] = x[p + j];
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::forall_res;
    use crate::util::rng::Rng;

    #[test]
    fn fused_matches_gather() {
        let mut rng = Rng::new(1);
        let head_dim = 16;
        let m = 5;
        let idx = vec![rng.choose_distinct(head_dim / 2, m)];
        let table = RopeTable::new(&idx, head_dim, 10_000.0);
        for pos in [0usize, 1, 7, 123] {
            let mut a: Vec<f32> = (0..2 * m).map(|_| rng.normal_f32()).collect();
            let mut b = a.clone();
            table.apply_fused(0, &mut a, pos);
            apply_gather(&mut b, pos, &idx[0], head_dim, 10_000.0);
            for (x, y) in a.iter().zip(&b) {
                assert!((x - y).abs() < 1e-5, "pos {pos}: {x} vs {y}");
            }
        }
    }

    #[test]
    fn fused_matches_full_when_all_pairs_kept() {
        let mut rng = Rng::new(2);
        for pairing in [Pairing::Half, Pairing::Interleaved] {
            let d = 12;
            let idx = vec![(0..d / 2).collect::<Vec<_>>()];
            let table = RopeTable::new(&idx, d, 10_000.0);
            let x: Vec<f32> = (0..d).map(|_| rng.normal_f32()).collect();
            let pos = 9;
            // full path in native layout
            let mut full = x.clone();
            apply_full(&mut full, pos, pairing, 10_000.0);
            // fused path in half layout
            let mut half = to_half_layout(&x, pairing);
            table.apply_fused(0, &mut half, pos);
            let back = from_half_layout(&half, pairing);
            for (a, b) in full.iter().zip(&back) {
                assert!((a - b).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn rope_preserves_norm() {
        forall_res(
            3,
            60,
            |r| {
                let m = r.range(1, 12);
                let x: Vec<f32> = (0..2 * m).map(|_| r.normal_f32()).collect();
                let idx = r.choose_distinct(16, m);
                let pos = r.below(2048);
                (x, idx, pos)
            },
            |(x, idx, pos)| {
                let table = RopeTable::new(&[idx.clone()], 32, 10_000.0);
                let mut y = x.clone();
                table.apply_fused(0, &mut y, *pos);
                let n0: f32 = x.iter().map(|v| v * v).sum();
                let n1: f32 = y.iter().map(|v| v * v).sum();
                if (n0.sqrt() - n1.sqrt()).abs() > 1e-3 * (1.0 + n0.sqrt()) {
                    return Err(format!("norm {n0} -> {n1}"));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn relative_position_property() {
        // <R_i q, R_j k> depends only on i - j.
        let mut rng = Rng::new(4);
        let m = 4;
        let idx = vec![rng.choose_distinct(8, m)];
        let table = RopeTable::new(&idx, 16, 100.0);
        let q: Vec<f32> = (0..2 * m).map(|_| rng.normal_f32()).collect();
        let k: Vec<f32> = (0..2 * m).map(|_| rng.normal_f32()).collect();
        let score = |i: usize, j: usize| {
            let mut qi = q.clone();
            let mut kj = k.clone();
            table.apply_fused(0, &mut qi, i);
            table.apply_fused(0, &mut kj, j);
            qi.iter().zip(&kj).map(|(a, b)| a * b).sum::<f32>()
        };
        assert!((score(5, 2) - score(103, 100)).abs() < 1e-3);
        assert!((score(0, 0) - score(77, 77)).abs() < 1e-3);
    }

    #[test]
    fn pos_zero_is_identity() {
        let table = RopeTable::new(&[vec![0, 2, 3]], 8, 10_000.0);
        let x = vec![1.0f32, 2.0, 3.0, 4.0, 5.0, 6.0];
        let mut y = x.clone();
        table.apply_fused(0, &mut y, 0);
        assert_eq!(x, y);
    }

    #[test]
    fn half_layout_roundtrip() {
        let mut rng = Rng::new(5);
        for pairing in [Pairing::Half, Pairing::Interleaved] {
            let x: Vec<f32> = (0..10).map(|_| rng.normal_f32()).collect();
            let rt = from_half_layout(&to_half_layout(&x, pairing), pairing);
            assert_eq!(x, rt);
        }
    }

    #[test]
    fn chunk_apply_matches_per_token_fused() {
        let mut rng = Rng::new(7);
        let (heads, m, s) = (3usize, 4usize, 6usize);
        let idx: Vec<Vec<usize>> = (0..heads).map(|_| rng.choose_distinct(8, m)).collect();
        let table = RopeTable::new(&idx, 16, 10_000.0);
        let w = 2 * m;
        let mut chunk: Vec<f32> = (0..s * heads * w).map(|_| rng.normal_f32()).collect();
        let orig = chunk.clone();
        table.apply_fused_chunk(&mut chunk, heads, 5);
        for t in 0..s {
            for h in 0..heads {
                let o = (t * heads + h) * w;
                let mut expect = orig[o..o + w].to_vec();
                table.apply_fused(h, &mut expect, 5 + t);
                assert_eq!(&chunk[o..o + w], &expect[..], "t{t} h{h}");
            }
        }
    }

    #[test]
    fn full_tokens_matches_per_row_apply_full() {
        let mut rng = Rng::new(8);
        for pairing in [Pairing::Half, Pairing::Interleaved] {
            let (heads, d, s) = (2usize, 8usize, 5usize);
            let mut chunk: Vec<f32> = (0..s * heads * d).map(|_| rng.normal_f32()).collect();
            let orig = chunk.clone();
            apply_full_tokens(&mut chunk, heads, d, 3, pairing, 10_000.0);
            for t in 0..s {
                for h in 0..heads {
                    let o = (t * heads + h) * d;
                    let mut expect = orig[o..o + d].to_vec();
                    apply_full(&mut expect, 3 + t, pairing, 10_000.0);
                    assert_eq!(&chunk[o..o + d], &expect[..], "t{t} h{h}");
                }
            }
        }
    }

    #[test]
    fn block_apply_positions() {
        let mut rng = Rng::new(6);
        let m = 3;
        let idx = vec![rng.choose_distinct(8, m)];
        let table = RopeTable::new(&idx, 16, 10_000.0);
        let s = 5;
        let mut block: Vec<f32> = (0..s * 2 * m).map(|_| rng.normal_f32()).collect();
        let orig = block.clone();
        table.apply_fused_block(0, &mut block, 10);
        for row in 0..s {
            let mut expect = orig[row * 2 * m..(row + 1) * 2 * m].to_vec();
            table.apply_fused(0, &mut expect, 10 + row);
            assert_eq!(&block[row * 2 * m..(row + 1) * 2 * m], &expect[..]);
        }
    }
}
