//! Single-sequence generation session over a `PjrtEngine` — the simplest
//! consumer of the runtime (examples, integration tests, batch-1 serving).

use anyhow::Result;

use crate::model::argmax;
use crate::runtime::{PjrtCache, PjrtContext, PjrtEngine};

pub struct Session<'a> {
    engine: &'a PjrtEngine,
    ctx: &'a PjrtContext,
    pub caches: Vec<PjrtCache>,
    pub pos: usize,
    pub last_logits: Vec<f32>,
}

impl<'a> Session<'a> {
    pub fn new(ctx: &'a PjrtContext, engine: &'a PjrtEngine) -> Result<Session<'a>> {
        Ok(Session {
            engine,
            ctx,
            caches: engine.empty_caches(1)?,
            pos: 0,
            last_logits: Vec::new(),
        })
    }

    /// Prefill using the smallest fitting bucket (prompt padded with zeros;
    /// positions beyond the prompt are overwritten by later decode steps).
    ///
    /// NOTE on bucket semantics: the exported prefill graph computes
    /// last-*bucket*-position logits, so for prompts shorter than the
    /// bucket we prefill `len-1` tokens step-wise... to keep semantics
    /// exact for any length we use the bucket only when the prompt length
    /// matches it exactly, otherwise fall back to stepwise decode-prefill.
    pub fn prefill(&mut self, prompt: &[u8]) -> Result<()> {
        let exact = self
            .engine
            .prefill_bucket(prompt.len())
            .ok()
            .filter(|(_, s)| *s == prompt.len());
        if let Some((graph, s)) = exact {
            let tokens: Vec<i32> = prompt.iter().map(|&b| b as i32).collect();
            debug_assert_eq!(tokens.len(), s);
            let out = self.engine.prefill(self.ctx, &graph, &tokens, 1)?;
            self.caches = out.caches;
            self.last_logits = out.logits;
            self.pos = prompt.len();
            return Ok(());
        }
        for &b in prompt {
            self.push(b)?;
        }
        Ok(())
    }

    /// Feed one token at the current position.
    pub fn push(&mut self, token: u8) -> Result<()> {
        let out = self.engine.decode(
            self.ctx,
            1,
            &[token as i32],
            &[self.pos as i32],
            &self.caches,
        )?;
        self.caches = out.caches;
        self.last_logits = out.logits;
        self.pos += 1;
        Ok(())
    }

    /// Greedy-generate `n` tokens.
    pub fn generate(&mut self, n: usize) -> Result<Vec<u8>> {
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            if self.pos >= self.engine.s_max {
                break;
            }
            let next = argmax(&self.last_logits) as u8;
            out.push(next);
            self.push(next)?;
        }
        Ok(out)
    }
}
