//! PJRT-backed serving backend: per-session host caches, batched decode
//! through the exported batch-bucket graphs with per-sequence positions.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

use crate::coordinator::scheduler::Backend;
use crate::coordinator::RequestId;
use crate::kvcache::PagedKvCache;
use crate::model::argmax;
use crate::runtime::{PjrtCache, PjrtContext, PjrtEngine};

pub struct PjrtBackend<'a> {
    ctx: &'a PjrtContext,
    engine: &'a PjrtEngine,
    sessions: BTreeMap<RequestId, Vec<PjrtCache>>,
    buckets: Vec<usize>,
    /// Zero cache used to pad partial batches (outputs discarded).
    pad_cache: Vec<PjrtCache>,
}

impl<'a> PjrtBackend<'a> {
    pub fn new(ctx: &'a PjrtContext, engine: &'a PjrtEngine) -> Result<PjrtBackend<'a>> {
        Ok(PjrtBackend {
            pad_cache: engine.empty_caches(1)?,
            buckets: engine.decode_batches(),
            ctx,
            engine,
            sessions: BTreeMap::new(),
        })
    }

    pub fn session_count(&self) -> usize {
        self.sessions.len()
    }

    /// Smallest exported bucket >= n.
    fn bucket_for(&self, n: usize) -> Result<usize> {
        self.buckets
            .iter()
            .copied()
            .find(|&b| b >= n)
            .with_context(|| format!("no decode bucket fits batch {n} (have {:?})", self.buckets))
    }

    /// Concatenate per-session [1, ...] caches into one [B, ...] batch.
    fn gather_batch(&self, ids: &[Option<RequestId>]) -> Result<Vec<PjrtCache>> {
        let b = ids.len();
        let mut out = Vec::with_capacity(self.engine.n_layers);
        for l in 0..self.engine.n_layers {
            let mut k = Vec::new();
            let mut v = Vec::new();
            for id in ids {
                let cache = match id {
                    Some(id) => self
                        .sessions
                        .get(id)
                        .with_context(|| format!("unknown session {id}"))?,
                    None => &self.pad_cache,
                };
                k.extend_from_slice(&cache[l].k);
                v.extend_from_slice(&cache[l].v);
            }
            let mut k_dims = self.pad_cache[l].k_dims.clone();
            let mut v_dims = self.pad_cache[l].v_dims.clone();
            k_dims[0] = b;
            v_dims[0] = b;
            out.push(PjrtCache { k, k_dims, v, v_dims });
        }
        Ok(out)
    }

    /// Split a [B, ...] batched cache back into per-session [1, ...] caches.
    fn scatter_batch(&mut self, ids: &[Option<RequestId>], caches: Vec<PjrtCache>) {
        for (l, c) in caches.into_iter().enumerate() {
            let kn = c.k.len() / ids.len();
            let vn = c.v.len() / ids.len();
            for (bi, id) in ids.iter().enumerate() {
                let Some(id) = id else { continue };
                let sess = self.sessions.get_mut(id).unwrap();
                sess[l].k.copy_from_slice(&c.k[bi * kn..(bi + 1) * kn]);
                sess[l].v.copy_from_slice(&c.v[bi * vn..(bi + 1) * vn]);
            }
        }
    }
}

impl<'a> Backend for PjrtBackend<'a> {
    fn s_max(&self) -> usize {
        self.engine.s_max
    }

    // Session caches are host literals re-uploaded per step; the
    // coordinator's paged allocator is accounting-only for this backend.
    fn prefill(
        &mut self,
        kv: &mut PagedKvCache,
        session: RequestId,
        prompt: &[u8],
    ) -> Result<Vec<f32>> {
        match self.prefill_chunk(kv, session, prompt, 0, true)? {
            Some(logits) => Ok(logits),
            None => unreachable!("last chunk always returns logits"),
        }
    }

    // Chunked prefill: the per-session host cache already carries decode
    // state forward token-by-token, so resuming a prompt at `pos0` is the
    // same decode-graph loop the whole-prompt path used.  A first chunk
    // whose length exactly matches an exported bucket keeps the AOT
    // prefill-graph fast path (with the default 128-token chunk budget
    // that is the `prefill128` bucket) whether or not it closes the
    // prompt — the graph's output caches seed the session either way.
    fn supports_chunked_prefill(&self) -> bool {
        true
    }

    fn prefill_chunk(
        &mut self,
        _kv: &mut PagedKvCache,
        session: RequestId,
        tokens: &[u8],
        pos0: usize,
        last: bool,
    ) -> Result<Option<Vec<f32>>> {
        if tokens.is_empty() {
            // Whole-prompt case and the degenerate empty last-chunk shape:
            // an empty chunk has no logits to return.
            bail!("empty prefill chunk (session {session}, pos {pos0})");
        }
        if pos0 == 0 {
            // Exact-bucket first chunks use the prefill graph; everything
            // else runs the decode graph token-by-token (same numerics,
            // verified in tests).
            if let Ok((graph, s)) = self.engine.prefill_bucket(tokens.len()) {
                if s == tokens.len() {
                    let ids: Vec<i32> = tokens.iter().map(|&b| b as i32).collect();
                    let out = self.engine.prefill(self.ctx, &graph, &ids, 1)?;
                    self.sessions.insert(session, out.caches);
                    return Ok(if last { Some(out.logits) } else { None });
                }
            }
            self.sessions.insert(session, self.engine.empty_caches(1)?);
        }
        let mut logits = Vec::new();
        for (i, &b) in tokens.iter().enumerate() {
            let cache = self
                .sessions
                .get(&session)
                .with_context(|| format!("unknown session {session}"))?;
            let out = self
                .engine
                .decode(self.ctx, 1, &[b as i32], &[(pos0 + i) as i32], cache)?;
            self.sessions.insert(session, out.caches);
            logits = out.logits;
        }
        Ok(if last { Some(logits) } else { None })
    }

    fn decode_batch(
        &mut self,
        _kv: &mut PagedKvCache,
        entries: &[(RequestId, u8, usize)],
    ) -> Result<Vec<Vec<f32>>> {
        let bucket = self.bucket_for(entries.len())?;
        let mut ids: Vec<Option<RequestId>> = entries.iter().map(|e| Some(e.0)).collect();
        let mut tokens: Vec<i32> = entries.iter().map(|e| e.1 as i32).collect();
        let mut pos: Vec<i32> = entries.iter().map(|e| e.2 as i32).collect();
        // Pad the batch to the bucket with inert slots (zero cache, pos 0 —
        // its cache write lands in the pad cache copy, which is discarded).
        while ids.len() < bucket {
            ids.push(None);
            tokens.push(0);
            pos.push(0);
        }
        let batch_cache = self.gather_batch(&ids)?;
        let out = self
            .engine
            .decode(self.ctx, bucket, &tokens, &pos, &batch_cache)?;
        self.scatter_batch(&ids, out.caches);
        let vocab = out.logits.len() / bucket;
        Ok((0..entries.len())
            .map(|i| out.logits[i * vocab..(i + 1) * vocab].to_vec())
            .collect())
    }

    fn drop_session(&mut self, session: RequestId) {
        self.sessions.remove(&session);
    }
}

/// Convenience: greedy-generate through the backend (used by tests).  The
/// caller supplies the paged allocator the backend decodes against
/// (storage-backed when the backend `wants_paged_storage`); the session's
/// blocks are released before returning.
///
/// Deliberately an independent argmax loop, NOT a delegation to
/// [`generate_sampled`] with greedy params: this is the v1 oracle the
/// sampled path is asserted bit-identical against in `tests/serving.rs`,
/// and delegating would make that identity tautological.
pub fn generate_once(
    backend: &mut dyn Backend,
    kv: &mut PagedKvCache,
    id: RequestId,
    prompt: &[u8],
    n: usize,
) -> Result<Vec<u8>> {
    let logits = backend.prefill(kv, id, prompt)?;
    let mut next = argmax(&logits) as u8;
    let mut pos = prompt.len();
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        out.push(next);
        let lg = backend.decode_batch(kv, &[(id, next, pos)])?;
        next = argmax(&lg[0]) as u8;
        pos += 1;
        if pos >= backend.s_max() {
            break;
        }
    }
    backend.drop_session(id);
    kv.release(id);
    Ok(out)
}

/// Single-session *sampled* generation through the backend — the
/// sequential (batch-1) reference the coordinator's batched sampled
/// decode is propchecked against in `tests/serving.rs`.  Consumes logits
/// in the same order as the v2 serve loop — the prompt's final prefill
/// logits name the first token, each decode step's logits name the next —
/// so the same `SamplingParams` reproduce the same generation.
pub fn generate_sampled(
    backend: &mut dyn Backend,
    kv: &mut PagedKvCache,
    id: RequestId,
    prompt: &[u8],
    n: usize,
    params: &crate::coordinator::SamplingParams,
) -> Result<Vec<u8>> {
    let mut sampler = crate::coordinator::Sampler::new(params);
    let logits = backend.prefill(kv, id, prompt)?;
    let mut out = Vec::with_capacity(n);
    if n > 0 {
        out.push(sampler.sample(&logits) as u8);
        let mut pos = prompt.len();
        while out.len() < n && pos < backend.s_max() {
            let lg = backend.decode_batch(kv, &[(id, *out.last().unwrap(), pos)])?;
            pos += 1;
            out.push(sampler.sample(&lg[0]) as u8);
        }
    }
    backend.drop_session(id);
    kv.release(id);
    Ok(out)
}
