//! PJRT runtime: load AOT HLO-text artifacts and execute them.
//!
//! `PjrtEngine` owns one compiled executable per exported graph
//! (prefill buckets + decode batch buckets per variant) and keeps the
//! variant's weights **device-resident** as `PjRtBuffer`s, so the decode
//! hot loop only uploads the per-step inputs (token, pos, caches) and never
//! re-marshals weights.
//!
//! Cache threading: the executables return `(logits, k_0..k_L, v_0..v_L)`
//! as one tuple buffer (that is how this PJRT build materialises tuples).
//! Each step therefore downloads the tuple and re-uploads the caches next
//! step.  The marshalling cost is identical *policy* for every method but
//! proportional to cache bytes — i.e. it scales with exactly the quantity
//! the paper compresses, so the relative latency shapes are preserved (and
//! measured separately from compute in the experiments).

pub mod backend;
pub mod session;

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::manifest::{HloGraph, Manifest, VariantEntry};
use crate::model::Weights;

/// Thin wrapper over the PJRT CPU client.
pub struct PjrtContext {
    pub client: xla::PjRtClient,
}

impl PjrtContext {
    pub fn cpu() -> Result<PjrtContext> {
        Ok(PjrtContext {
            client: xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e:?}"))?,
        })
    }

    pub fn compile_file(&self, path: &Path) -> Result<xla::PjRtLoadedExecutable> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?,
        )
        .map_err(|e| anyhow!("parsing {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {}: {e:?}", path.display()))
    }
}

/// One compiled graph + its signature.
pub struct CompiledGraph {
    pub info: HloGraph,
    pub exe: xla::PjRtLoadedExecutable,
}

/// Host-side per-layer cache pair (re-uploaded per step).
#[derive(Debug, Clone)]
pub struct PjrtCache {
    pub k: Vec<f32>,
    pub k_dims: Vec<usize>,
    pub v: Vec<f32>,
    pub v_dims: Vec<usize>,
}

/// Decode-step output.
pub struct StepOut {
    pub logits: Vec<f32>,
    pub caches: Vec<PjrtCache>,
}

/// A variant loaded for serving: compiled graphs + device-resident weights.
pub struct PjrtEngine {
    pub model: String,
    pub variant: String,
    pub n_layers: usize,
    pub n_kv_heads: usize,
    pub s_max: usize,
    pub k_rank: Vec<usize>,
    pub v_rank: Vec<usize>,
    graphs: BTreeMap<String, CompiledGraph>,
    weight_bufs: Vec<xla::PjRtBuffer>,
}

// NOTE: uploads go through `buffer_from_host_buffer`, whose C++ shim uses
// HostBufferSemantics::kImmutableOnlyDuringCall (synchronous copy).  The
// literal-based upload path (`BufferFromHostLiteral`) is asynchronous in
// this PJRT build and the binding drops the literal before the transfer
// completes — a use-after-free that aborts the process.  Do not use it.
fn upload_f32(
    ctx: &PjrtContext,
    data: &[f32],
    dims: &[usize],
) -> Result<xla::PjRtBuffer> {
    let device = ctx.client.devices().into_iter().next().context("no device")?;
    ctx.client
        .buffer_from_host_buffer(data, dims, Some(&device))
        .map_err(|e| anyhow!("upload f32 {dims:?}: {e:?}"))
}

fn upload_i32(
    ctx: &PjrtContext,
    data: &[i32],
    dims: &[usize],
) -> Result<xla::PjRtBuffer> {
    let device = ctx.client.devices().into_iter().next().context("no device")?;
    ctx.client
        .buffer_from_host_buffer(data, dims, Some(&device))
        .map_err(|e| anyhow!("upload i32 {dims:?}: {e:?}"))
}

impl PjrtEngine {
    /// Compile all exported graphs of `model/variant` and upload weights.
    pub fn load(
        ctx: &PjrtContext,
        manifest: &Manifest,
        model: &str,
        variant: &str,
    ) -> Result<PjrtEngine> {
        let entry = manifest.model(model)?;
        let ve: &VariantEntry = entry
            .variants
            .get(variant)
            .with_context(|| format!("variant {variant} of {model}"))?;
        let graphs_info = entry
            .hlo
            .get(variant)
            .with_context(|| format!("no HLO graphs exported for {model}/{variant}"))?;

        let mut graphs = BTreeMap::new();
        let mut weight_names: Option<Vec<String>> = None;
        for (name, info) in graphs_info {
            let exe = ctx.compile_file(&manifest.root.join(&info.path))?;
            if let Some(ref names) = weight_names {
                if names != &info.weight_names {
                    bail!("inconsistent weight ordering across graphs of {variant}");
                }
            } else {
                weight_names = Some(info.weight_names.clone());
            }
            graphs.insert(name.clone(), CompiledGraph { info: info.clone(), exe });
        }
        let weight_names = weight_names.context("variant has no graphs")?;

        // Upload weights once; reuse buffers across every execution.
        let weights = Weights::load(manifest, ve)?;
        let mut weight_bufs = Vec::with_capacity(weight_names.len());
        for name in &weight_names {
            let t = weights.get(name);
            weight_bufs.push(upload_f32(ctx, &t.data, &t.shape)?);
        }

        let any = graphs.values().next().context("no graphs")?;
        Ok(PjrtEngine {
            model: model.to_string(),
            variant: variant.to_string(),
            n_layers: any.info.k_rank.len(),
            n_kv_heads: entry.config.n_kv_heads,
            s_max: any.info.s_max,
            k_rank: any.info.k_rank.clone(),
            v_rank: any.info.v_rank.clone(),
            graphs,
            weight_bufs,
        })
    }

    pub fn graph_names(&self) -> Vec<&str> {
        self.graphs.keys().map(|s| s.as_str()).collect()
    }

    pub fn graph(&self, name: &str) -> Result<&CompiledGraph> {
        self.graphs
            .get(name)
            .with_context(|| format!("graph {name} not loaded for {}", self.variant))
    }

    /// Pick the smallest prefill bucket that fits `len` tokens.
    pub fn prefill_bucket(&self, len: usize) -> Result<(String, usize)> {
        let mut best: Option<(String, usize)> = None;
        for (name, g) in &self.graphs {
            if g.info.kind == "prefill"
                && g.info.seq >= len
                && best.as_ref().map(|(_, s)| g.info.seq < *s).unwrap_or(true)
            {
                best = Some((name.clone(), g.info.seq));
            }
        }
        best.with_context(|| format!("no prefill bucket fits length {len}"))
    }

    pub fn decode_graph(&self, batch: usize) -> Result<&CompiledGraph> {
        self.graph(&format!("decode_b{batch}"))
    }

    pub fn decode_batches(&self) -> Vec<usize> {
        let mut v: Vec<usize> = self
            .graphs
            .values()
            .filter(|g| g.info.kind == "decode")
            .map(|g| g.info.batch)
            .collect();
        v.sort_unstable();
        v.dedup();
        v
    }

    /// Execute the prefill graph on (padded) `tokens` [B, S_bucket].
    pub fn prefill(
        &self,
        ctx: &PjrtContext,
        graph: &str,
        tokens: &[i32],
        batch: usize,
    ) -> Result<StepOut> {
        let g = self.graph(graph)?;
        let s = g.info.seq;
        assert_eq!(tokens.len(), batch * s, "tokens must be padded to the bucket");
        let tok_buf = upload_i32(ctx, tokens, &[batch, s])?;
        let mut args: Vec<&xla::PjRtBuffer> = self.weight_bufs.iter().collect();
        args.push(&tok_buf);
        let out = g.exe.execute_b(&args).map_err(|e| anyhow!("prefill exec: {e:?}"))?;
        self.unpack(out, batch)
    }

    /// Execute one decode step for a batch of sessions, each at its own
    /// position (`pos[b]`) — the continuous batcher mixes offsets freely.
    pub fn decode(
        &self,
        ctx: &PjrtContext,
        batch: usize,
        tokens: &[i32],
        pos: &[i32],
        caches: &[PjrtCache],
    ) -> Result<StepOut> {
        let g = self.decode_graph(batch)?;
        assert_eq!(tokens.len(), batch);
        assert_eq!(pos.len(), batch);
        assert_eq!(caches.len(), self.n_layers);
        let tok_buf = upload_i32(ctx, tokens, &[batch])?;
        let pos_buf = upload_i32(ctx, pos, &[batch])?;

        let mut cache_bufs = Vec::with_capacity(2 * self.n_layers);
        for c in caches {
            cache_bufs.push(upload_f32(ctx, &c.k, &c.k_dims)?);
        }
        for c in caches {
            cache_bufs.push(upload_f32(ctx, &c.v, &c.v_dims)?);
        }
        let mut args: Vec<&xla::PjRtBuffer> = self.weight_bufs.iter().collect();
        args.push(&tok_buf);
        args.push(&pos_buf);
        args.extend(cache_bufs.iter());
        let out = g.exe.execute_b(&args).map_err(|e| anyhow!("decode exec: {e:?}"))?;
        self.unpack(out, batch)
    }

    /// Outputs arrive as one tuple buffer: (logits, k_0..k_L, v_0..v_L).
    fn unpack(&self, out: Vec<Vec<xla::PjRtBuffer>>, batch: usize) -> Result<StepOut> {
        let bufs = out.into_iter().next().context("no replica output")?;
        let mut literals: Vec<xla::Literal> = Vec::new();
        if bufs.len() == 1 {
            let lit = bufs[0]
                .to_literal_sync()
                .map_err(|e| anyhow!("output download: {e:?}"))?;
            literals = lit.to_tuple().map_err(|e| anyhow!("tuple: {e:?}"))?;
        } else {
            for b in &bufs {
                literals.push(
                    b.to_literal_sync()
                        .map_err(|e| anyhow!("output download: {e:?}"))?,
                );
            }
        }
        if literals.len() != 1 + 2 * self.n_layers {
            bail!(
                "unexpected output arity {} (want {})",
                literals.len(),
                1 + 2 * self.n_layers
            );
        }
        let mut iter = literals.into_iter();
        let logits = iter
            .next()
            .unwrap()
            .to_vec::<f32>()
            .map_err(|e| anyhow!("logits: {e:?}"))?;
        let mut ks: Vec<Vec<f32>> = Vec::with_capacity(self.n_layers);
        for _ in 0..self.n_layers {
            ks.push(
                iter.next()
                    .unwrap()
                    .to_vec::<f32>()
                    .map_err(|e| anyhow!("k cache: {e:?}"))?,
            );
        }
        let mut caches = Vec::with_capacity(self.n_layers);
        for (l, k) in ks.into_iter().enumerate() {
            let v = iter
                .next()
                .unwrap()
                .to_vec::<f32>()
                .map_err(|e| anyhow!("v cache: {e:?}"))?;
            caches.push(PjrtCache {
                k,
                k_dims: vec![batch, self.n_kv_heads, self.s_max, self.k_rank[l]],
                v,
                v_dims: vec![batch, self.n_kv_heads, self.s_max, self.v_rank[l]],
            });
        }
        Ok(StepOut { logits, caches })
    }

    /// Zeroed host caches for a fresh sequence.
    pub fn empty_caches(&self, batch: usize) -> Result<Vec<PjrtCache>> {
        let mut out = Vec::with_capacity(self.n_layers);
        for l in 0..self.n_layers {
            let kdims = vec![batch, self.n_kv_heads, self.s_max, self.k_rank[l]];
            let vdims = vec![batch, self.n_kv_heads, self.s_max, self.v_rank[l]];
            out.push(PjrtCache {
                k: vec![0.0; kdims.iter().product()],
                k_dims: kdims,
                v: vec![0.0; vdims.iter().product()],
                v_dims: vdims,
            });
        }
        Ok(out)
    }

    /// Cache bytes per sequence at full s_max (marshalled per decode step).
    pub fn cache_bytes(&self, batch: usize) -> usize {
        4 * batch
            * self.n_kv_heads
            * self.s_max
            * (self.k_rank.iter().sum::<usize>() + self.v_rank.iter().sum::<usize>())
    }
}
