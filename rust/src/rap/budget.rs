//! Adaptive budget allocation (Algorithm 2) — native mirror of
//! `python/compile/rap/budget.py`, used by the `plan` CLI and by the
//! property-test suite (the water-filling projection's invariants are easy
//! to state and easy to get wrong).

use crate::config::ModelConfig;

/// Per-(layer, K/V) group Fisher mass.
#[derive(Debug, Clone)]
pub struct GroupScores {
    /// sum of pair scores per layer for W_k.
    pub k: Vec<f64>,
    /// sum of column scores per layer for W_v.
    pub v: Vec<f64>,
}

/// Algorithm 2: returns per-layer compression ratios (rho_k, rho_v) with
/// mean exactly `rho` and every entry in [0, 1].
pub fn allocate(scores: &GroupScores, rho: f64) -> (Vec<f64>, Vec<f64>) {
    let l = scores.k.len();
    assert_eq!(scores.v.len(), l);
    let mut flat: Vec<f64> = Vec::with_capacity(2 * l);
    for i in 0..l {
        flat.push(scores.k[i]);
        flat.push(scores.v[i]);
    }
    let n = flat.len();
    let sc: f64 = flat.iter().sum();
    let mut rho_i: Vec<f64> = if sc <= 0.0 || n <= 1 {
        vec![rho; n]
    } else {
        flat.iter()
            // Alg. 2 line 6: anti-proportional to sensitivity, normalised.
            .map(|&s| rho * (1.0 - s / sc) / (1.0 - 1.0 / n as f64))
            .collect()
    };
    for v in rho_i.iter_mut() {
        *v = v.clamp(0.0, 1.0);
    }
    project_mean(&mut rho_i, rho);
    let rho_k = rho_i.iter().step_by(2).copied().collect();
    let rho_v = rho_i.iter().skip(1).step_by(2).copied().collect();
    (rho_k, rho_v)
}

/// Project onto {y in [0,1]^n : mean(y) = target} by iterative
/// water-filling (Alg. 2 line 9).
pub fn project_mean(x: &mut [f64], target: f64) {
    let target = target.clamp(0.0, 1.0);
    let n = x.len();
    if n == 0 {
        return;
    }
    for v in x.iter_mut() {
        *v = v.clamp(0.0, 1.0);
    }
    for _ in 0..200 {
        let mean = x.iter().sum::<f64>() / n as f64;
        let resid = target - mean;
        if resid.abs() < 1e-13 {
            break;
        }
        let free: Vec<usize> = x
            .iter()
            .enumerate()
            .filter(|(_, &v)| if resid > 0.0 { v < 1.0 } else { v > 0.0 })
            .map(|(i, _)| i)
            .collect();
        if free.is_empty() {
            break;
        }
        let delta = resid * n as f64 / free.len() as f64;
        for &i in &free {
            x[i] = (x[i] + delta).clamp(0.0, 1.0);
        }
    }
}

/// Integerise group ratios into retained pair counts / V ranks
/// (head-uniform within a layer, §4.2 point 2), with a greedy fix-up so the
/// achieved global KV ratio matches the target as closely as integers allow.
pub fn ranks_from_ratios(
    cfg: &ModelConfig,
    rho_k: &[f64],
    rho_v: &[f64],
) -> (Vec<usize>, Vec<usize>) {
    let p = cfg.n_pairs();
    let dh = cfg.head_dim;
    let mut m: Vec<usize> = rho_k
        .iter()
        .map(|r| (((1.0 - r) * p as f64).round() as usize).clamp(1, p))
        .collect();
    let mut rv: Vec<usize> = rho_v
        .iter()
        .map(|r| (((1.0 - r) * dh as f64).round() as usize).clamp(1, dh))
        .collect();

    let mean_rho =
        (rho_k.iter().sum::<f64>() + rho_v.iter().sum::<f64>()) / (2 * cfg.n_layers) as f64;
    let target_keep = (1.0 - mean_rho) * (2 * dh * cfg.n_layers) as f64;

    for _ in 0..4 * cfg.n_layers {
        let total: isize = m.iter().map(|&x| 2 * x as isize).sum::<isize>()
            + rv.iter().map(|&x| x as isize).sum::<isize>();
        let diff = target_keep - total as f64;
        if diff.abs() < 1.0 {
            break;
        }
        if diff > 0.0 {
            // grow the width with the largest rounding deficit
            let mut best: Option<(bool, usize, f64)> = None;
            for i in 0..cfg.n_layers {
                if m[i] < p {
                    let deficit = (1.0 - rho_k[i]) * p as f64 - m[i] as f64;
                    if best.map(|b| deficit > b.2).unwrap_or(true) {
                        best = Some((true, i, deficit));
                    }
                }
                if rv[i] < dh {
                    let deficit = (1.0 - rho_v[i]) * dh as f64 - rv[i] as f64;
                    if best.map(|b| deficit > b.2).unwrap_or(true) {
                        best = Some((false, i, deficit));
                    }
                }
            }
            match best {
                Some((true, i, _)) => m[i] += 1,
                Some((false, i, _)) => rv[i] += 1,
                None => break,
            }
        } else {
            let mut best: Option<(bool, usize, f64)> = None;
            for i in 0..cfg.n_layers {
                if m[i] > 1 {
                    let excess = m[i] as f64 - (1.0 - rho_k[i]) * p as f64;
                    if best.map(|b| excess > b.2).unwrap_or(true) {
                        best = Some((true, i, excess));
                    }
                }
                if rv[i] > 1 {
                    let excess = rv[i] as f64 - (1.0 - rho_v[i]) * dh as f64;
                    if best.map(|b| excess > b.2).unwrap_or(true) {
                        best = Some((false, i, excess));
                    }
                }
            }
            match best {
                Some((true, i, _)) => m[i] -= 1,
                Some((false, i, _)) => rv[i] -= 1,
                None => break,
            }
        }
    }
    (m, rv)
}

/// Uniform arm of the Fig. 13 ablation.
pub fn uniform_ranks(cfg: &ModelConfig, rho: f64) -> (Vec<usize>, Vec<usize>) {
    let m = (((1.0 - rho) * cfg.n_pairs() as f64).round() as usize).clamp(1, cfg.n_pairs());
    let rv = (((1.0 - rho) * cfg.head_dim as f64).round() as usize).clamp(1, cfg.head_dim);
    (vec![m; cfg.n_layers], vec![rv; cfg.n_layers])
}

pub fn achieved_kv_ratio(cfg: &ModelConfig, m: &[usize], rv: &[usize]) -> f64 {
    let kept: usize = m.iter().map(|&x| 2 * x).sum::<usize>() + rv.iter().sum::<usize>();
    kept as f64 / (2 * cfg.head_dim * cfg.n_layers) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::forall_res;

    fn tiny_cfg(layers: usize) -> ModelConfig {
        let mut c = ModelConfig::paper_llama();
        c.n_layers = layers;
        c
    }

    #[test]
    fn mean_is_exact() {
        let s = GroupScores {
            k: vec![1.0, 5.0, 2.0, 0.5],
            v: vec![9.0, 3.0, 4.0, 1.0],
        };
        for rho in [0.1, 0.3, 0.5, 0.8] {
            let (rk, rv) = allocate(&s, rho);
            let mean = (rk.iter().sum::<f64>() + rv.iter().sum::<f64>()) / 8.0;
            assert!((mean - rho).abs() < 1e-9, "rho {rho}: mean {mean}");
            assert!(rk.iter().chain(&rv).all(|&r| (0.0..=1.0).contains(&r)));
        }
    }

    #[test]
    fn sensitivity_ordering() {
        let s = GroupScores {
            k: vec![100.0, 0.01],
            v: vec![1.0, 1.0],
        };
        let (rk, _) = allocate(&s, 0.3);
        assert!(rk[0] < rk[1], "sensitive layer pruned more: {rk:?}");
    }

    #[test]
    fn project_mean_properties() {
        forall_res(
            11,
            200,
            |r| {
                let n = r.range(1, 40);
                let xs: Vec<f64> = (0..n).map(|_| r.f64() * 3.0 - 1.0).collect();
                let t = r.f64();
                (xs, t)
            },
            |(xs, t)| {
                let mut y = xs.clone();
                project_mean(&mut y, *t);
                if y.iter().any(|&v| !(-1e-12..=1.0 + 1e-12).contains(&v)) {
                    return Err(format!("range violated: {y:?}"));
                }
                let mean = y.iter().sum::<f64>() / y.len() as f64;
                if (mean - t).abs() > 1e-7 {
                    return Err(format!("mean {mean} != {t}"));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn ranks_respect_bounds_and_target() {
        let cfg = tiny_cfg(6);
        forall_res(
            12,
            60,
            |r| {
                let rho = 0.05 + r.f64() * 0.9;
                let k: Vec<f64> = (0..6).map(|_| r.f64() * 10.0 + 0.01).collect();
                let v: Vec<f64> = (0..6).map(|_| r.f64() * 10.0 + 0.01).collect();
                (rho, k, v)
            },
            |(rho, k, v)| {
                let s = GroupScores { k: k.clone(), v: v.clone() };
                let (rk, rv) = allocate(&s, *rho);
                let (m, rvv) = ranks_from_ratios(&cfg, &rk, &rv);
                if m.iter().any(|&x| x < 1 || x > cfg.n_pairs()) {
                    return Err(format!("m out of range {m:?}"));
                }
                if rvv.iter().any(|&x| x < 1 || x > cfg.head_dim) {
                    return Err(format!("rv out of range {rvv:?}"));
                }
                let achieved = achieved_kv_ratio(&cfg, &m, &rvv);
                if (achieved - (1.0 - rho)).abs() > 0.05 {
                    return Err(format!("achieved {achieved} vs target {}", 1.0 - rho));
                }
                Ok(())
            },
        );
    }

    #[test]
    fn uniform_matches_rho() {
        let cfg = tiny_cfg(4);
        let (m, rv) = uniform_ranks(&cfg, 0.5);
        assert_eq!(m, vec![cfg.n_pairs() / 2; 4]);
        assert_eq!(rv, vec![cfg.head_dim / 2; 4]);
        let a = achieved_kv_ratio(&cfg, &m, &rv);
        assert!((a - 0.5).abs() < 1e-9);
    }
}
