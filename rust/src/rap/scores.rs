//! Score aggregation (paper Eq. 7): fold per-entry importance mass
//! (squared gradients for Fisher, squared weights for the magnitude
//! ablation) into per-(head, pair) scores for K and per-(head, column)
//! scores for V.

use crate::config::ModelConfig;
use crate::tensor::Tensor;

/// Per-layer scores.
#[derive(Debug, Clone)]
pub struct LayerScores {
    /// [n_kv_heads][n_pairs]
    pub k_pairs: Vec<Vec<f64>>,
    /// [n_kv_heads][head_dim]
    pub v_cols: Vec<Vec<f64>>,
}

impl LayerScores {
    pub fn k_total(&self) -> f64 {
        self.k_pairs.iter().flatten().sum()
    }

    pub fn v_total(&self) -> f64 {
        self.v_cols.iter().flatten().sum()
    }
}

/// Aggregate an importance mass matrix [D, Hkv*dh] (already squared) into
/// pair scores: sigma_p = sum over rows of both pair columns (Eq. 7).
pub fn pair_scores(cfg: &ModelConfig, mass_k: &Tensor, mass_v: &Tensor) -> LayerScores {
    let (d, hd) = mass_k.dims2();
    assert_eq!(hd, cfg.kv_dim());
    assert_eq!(mass_v.dims2(), (d, hd));
    let dh = cfg.head_dim;
    let p = cfg.n_pairs();

    // Column sums per head.
    let col_sum = |mass: &Tensor| -> Vec<Vec<f64>> {
        let mut out = vec![vec![0.0f64; dh]; cfg.n_kv_heads];
        for i in 0..d {
            let row = mass.row(i);
            for h in 0..cfg.n_kv_heads {
                for c in 0..dh {
                    out[h][c] += row[h * dh + c] as f64;
                }
            }
        }
        out
    };

    let ck = col_sum(mass_k);
    let cv = col_sum(mass_v);
    let k_pairs = (0..cfg.n_kv_heads)
        .map(|h| {
            (0..p)
                .map(|j| {
                    let (a, b) = cfg.pairing.pair_cols(j, dh);
                    ck[h][a] + ck[h][b]
                })
                .collect()
        })
        .collect();
    LayerScores {
        k_pairs,
        v_cols: cv,
    }
}

/// Magnitude scoring (Fig. 13 "M" arms): mass = W ⊙ W.
pub fn magnitude_mass(w: &Tensor) -> Tensor {
    Tensor::new(
        w.shape.clone(),
        w.data.iter().map(|&x| x * x).collect(),
    )
}

/// Group totals feeding Algorithm 2.
pub fn group_scores(layers: &[LayerScores]) -> crate::rap::budget::GroupScores {
    crate::rap::budget::GroupScores {
        k: layers.iter().map(|l| l.k_total()).collect(),
        v: layers.iter().map(|l| l.v_total()).collect(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Pairing;

    fn cfg() -> ModelConfig {
        ModelConfig {
            name: "t".into(),
            vocab: 8,
            d_model: 4,
            n_layers: 1,
            n_heads: 2,
            n_kv_heads: 2,
            head_dim: 4,
            mlp_hidden: 8,
            max_seq: 16,
            rope_theta: 10_000.0,
            pairing: Pairing::Half,
            norm_eps: 1e-5,
        }
    }

    #[test]
    fn pair_scores_sum_both_columns() {
        let c = cfg();
        // head 0: column 0 has mass 1 per row, column 2 has mass 2 per row.
        // half pairing with dh=4: pair 0 = (0, 2), pair 1 = (1, 3).
        let mut mk = Tensor::zeros(vec![4, 8]);
        for i in 0..4 {
            mk.set2(i, 0, 1.0);
            mk.set2(i, 2, 2.0);
        }
        let mv = Tensor::zeros(vec![4, 8]);
        let s = pair_scores(&c, &mk, &mv);
        assert!((s.k_pairs[0][0] - 12.0).abs() < 1e-9); // (1+2)*4 rows
        assert_eq!(s.k_pairs[0][1], 0.0);
        assert_eq!(s.k_pairs[1][0], 0.0);
    }

    #[test]
    fn magnitude_mass_squares() {
        let w = Tensor::new(vec![1, 3], vec![1.0, -2.0, 3.0]);
        assert_eq!(magnitude_mass(&w).data, vec![1.0, 4.0, 9.0]);
    }

    #[test]
    fn group_scores_totals() {
        let l = LayerScores {
            k_pairs: vec![vec![1.0, 2.0], vec![3.0, 4.0]],
            v_cols: vec![vec![0.5; 4], vec![0.25; 4]],
        };
        let g = group_scores(&[l]);
        assert!((g.k[0] - 10.0).abs() < 1e-9);
        assert!((g.v[0] - 3.0).abs() < 1e-9);
    }
}
