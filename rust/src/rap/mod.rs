//! RAP: RoPE-Aligned Pruning — the paper's §4 pipeline, natively.
//!
//! `budget` — Algorithm 2 adaptive allocation (property-tested invariants).
//! `plan`   — pair selection, A/B construction (Eq. 8), W_q absorption
//!            (Eq. 9–10), fused RoPE tables.
//! `scores` — pair/column score aggregation (Eq. 7) from weight gradients
//!            or magnitudes.
//!
//! The Python pipeline (`python/compile/rap/`) is the authoritative producer
//! of shipped artifacts (it owns training and Fisher estimation); this
//! module reproduces the post-scoring stages natively so the planner can be
//! driven, inspected and property-tested from Rust, and so the coordinator
//! can construct plans for synthetic configurations (cost model, benches).

pub mod budget;
pub mod plan;
pub mod scores;
