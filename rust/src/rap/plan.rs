//! RAP construction in Rust (paper §4.3): pair selection, A/B gather,
//! absorption of B_k into W_q, and the explicit binary expansion used by
//! tests.  Operates on `tensor::Tensor` weights, mirroring
//! `python/compile/rap/prune.py` so the plan can be computed natively.

use crate::config::ModelConfig;
use crate::rope::RopeTable;
use crate::tensor::Tensor;

/// Select the top-m pairs per head from scores [n_heads][n_pairs];
/// returns indices sorted ascending (stable on ties by index).
pub fn select_pairs(scores: &[Vec<f64>], m: usize) -> Vec<Vec<usize>> {
    scores
        .iter()
        .map(|row| {
            let mut idx: Vec<usize> = (0..row.len()).collect();
            idx.sort_by(|&a, &b| {
                row[b].partial_cmp(&row[a]).unwrap().then(a.cmp(&b))
            });
            let mut keep = idx[..m].to_vec();
            keep.sort_unstable();
            keep
        })
        .collect()
}

/// Gather retained RoPE-pair columns of a [D, H*dh] projection into the
/// canonical half layout: [D, H*2m].
pub fn gather_pair_columns(
    cfg: &ModelConfig,
    w: &Tensor,
    n_heads: usize,
    pair_idx: &[Vec<usize>],
) -> Tensor {
    let (d, hd) = w.dims2();
    let dh = cfg.head_dim;
    assert_eq!(hd, n_heads * dh);
    let m = pair_idx[0].len();
    let mut cols = Vec::with_capacity(n_heads * 2 * m);
    for (h, idx) in pair_idx.iter().enumerate() {
        assert_eq!(idx.len(), m, "head-uniform m required");
        let base = h * dh;
        for &j in idx {
            cols.push(base + cfg.pairing.pair_cols(j, dh).0);
        }
        for &j in idx {
            cols.push(base + cfg.pairing.pair_cols(j, dh).1);
        }
    }
    let g = w.gather_cols(&cols);
    debug_assert_eq!(g.dims2(), (d, n_heads * 2 * m));
    g
}

/// Absorb B_k^T into W_q (Eq. 10): gather W_q's columns at the KV group's
/// retained pairs.  wq: [D, H*dh] -> [D, H*2m].
pub fn absorb_bk_into_wq(cfg: &ModelConfig, wq: &Tensor, pair_idx: &[Vec<usize>]) -> Tensor {
    let group = cfg.group_size();
    let q_idx: Vec<Vec<usize>> = (0..cfg.n_heads)
        .map(|h| pair_idx[h / group].clone())
        .collect();
    gather_pair_columns(cfg, wq, cfg.n_heads, &q_idx)
}

/// The explicit binary expansion B of Eq. 8 for one head: [2m, dh].
/// Runtime never materialises it (that is the point of absorption); tests
/// use it for the commutativity identities.
pub fn expansion_matrix(cfg: &ModelConfig, pair_idx_h: &[usize]) -> Tensor {
    let m = pair_idx_h.len();
    let dh = cfg.head_dim;
    let mut b = Tensor::zeros(vec![2 * m, dh]);
    for (i, &j) in pair_idx_h.iter().enumerate() {
        let (a_col, b_col) = cfg.pairing.pair_cols(j, dh);
        b.set2(i, a_col, 1.0);
        b.set2(m + i, b_col, 1.0);
    }
    b
}

/// A complete per-layer RAP plan: retained pairs + the fused RoPE tables
/// for K (per KV head) and Q (per query head, via its group).
#[derive(Debug, Clone)]
pub struct LayerPlan {
    pub pair_idx: Vec<Vec<usize>>,
    pub m: usize,
    pub k_table: RopeTable,
    pub q_table: RopeTable,
}

impl LayerPlan {
    pub fn new(cfg: &ModelConfig, pair_idx: Vec<Vec<usize>>) -> LayerPlan {
        let m = pair_idx[0].len();
        let k_table = RopeTable::new(&pair_idx, cfg.head_dim, cfg.rope_theta);
        let group = cfg.group_size();
        let q_idx: Vec<Vec<usize>> = (0..cfg.n_heads)
            .map(|h| pair_idx[h / group].clone())
            .collect();
        let q_table = RopeTable::new(&q_idx, cfg.head_dim, cfg.rope_theta);
        LayerPlan {
            pair_idx,
            m,
            k_table,
            q_table,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Pairing;
    use crate::tensor::ops::matmul;
    use crate::util::rng::Rng;

    fn cfg(pairing: Pairing) -> ModelConfig {
        ModelConfig {
            name: "t".into(),
            vocab: 64,
            d_model: 32,
            n_layers: 1,
            n_heads: 4,
            n_kv_heads: 2,
            head_dim: 16,
            mlp_hidden: 32,
            max_seq: 64,
            rope_theta: 10_000.0,
            pairing,
            norm_eps: 1e-5,
        }
    }

    #[test]
    fn select_pairs_top_m() {
        let scores = vec![vec![5.0, 1.0, 9.0, 2.0], vec![0.1, 0.4, 0.2, 0.3]];
        let idx = select_pairs(&scores, 2);
        assert_eq!(idx[0], vec![0, 2]);
        assert_eq!(idx[1], vec![1, 3]);
    }

    #[test]
    fn select_pairs_tie_stability() {
        let scores = vec![vec![1.0, 1.0, 1.0, 1.0]];
        assert_eq!(select_pairs(&scores, 2)[0], vec![0, 1]);
    }

    #[test]
    fn gather_equals_w_bt() {
        // A = W B^T for each head and both pairing strategies.
        for pairing in [Pairing::Half, Pairing::Interleaved] {
            let c = cfg(pairing);
            let mut rng = Rng::new(1);
            let w = Tensor::randn(vec![c.d_model, c.kv_dim()], 1.0, &mut rng);
            let m = 5;
            let idx: Vec<Vec<usize>> = (0..c.n_kv_heads)
                .map(|_| rng.choose_distinct(c.n_pairs(), m))
                .collect();
            let a = gather_pair_columns(&c, &w, c.n_kv_heads, &idx);
            for h in 0..c.n_kv_heads {
                let b = expansion_matrix(&c, &idx[h]);
                let wh = w.gather_cols(
                    &(h * c.head_dim..(h + 1) * c.head_dim).collect::<Vec<_>>(),
                );
                let expect = matmul(&wh, &b.transpose2());
                let got = a.gather_cols(
                    &(h * 2 * m..(h + 1) * 2 * m).collect::<Vec<_>>(),
                );
                assert!(got.max_abs_diff(&expect) < 1e-6);
            }
        }
    }

    #[test]
    fn expansion_matrix_orthonormal_binary() {
        let c = cfg(Pairing::Half);
        let mut rng = Rng::new(2);
        let idx = rng.choose_distinct(c.n_pairs(), 4);
        let b = expansion_matrix(&c, &idx);
        let bbt = matmul(&b, &b.transpose2());
        for i in 0..8 {
            for j in 0..8 {
                let expect = if i == j { 1.0 } else { 0.0 };
                assert_eq!(bbt.at2(i, j), expect);
            }
        }
        assert!(b.data.iter().all(|&v| v == 0.0 || v == 1.0));
    }

    #[test]
    fn commutativity_rope_xa_b_equals_rope_xab() {
        // The paper's Definition 1.1 in rust arithmetic.
        for pairing in [Pairing::Half, Pairing::Interleaved] {
            let c = cfg(pairing);
            let mut rng = Rng::new(3);
            let m = 5;
            let idx = rng.choose_distinct(c.n_pairs(), m);
            let b = expansion_matrix(&c, &idx);
            let table = RopeTable::new(&[idx.clone()], c.head_dim, c.rope_theta);
            for pos in [0usize, 3, 57] {
                let xa: Vec<f32> = (0..2 * m).map(|_| rng.normal_f32()).collect();
                // left: rotate latent then expand
                let mut lat = xa.clone();
                table.apply_fused(0, &mut lat, pos);
                let left = matmul(&Tensor::new(vec![1, 2 * m], lat), &b);
                // right: expand then full index-aware rope
                let mut full = matmul(&Tensor::new(vec![1, 2 * m], xa), &b);
                crate::rope::apply_full(&mut full.data, pos, pairing, c.rope_theta);
                assert!(
                    left.max_abs_diff(&full) < 1e-5,
                    "{pairing:?} pos {pos}: {}",
                    left.max_abs_diff(&full)
                );
            }
        }
    }

    #[test]
    fn absorbed_wq_width_and_group_mapping() {
        let c = cfg(Pairing::Half);
        let mut rng = Rng::new(4);
        let wq = Tensor::randn(vec![c.d_model, c.q_dim()], 1.0, &mut rng);
        let idx: Vec<Vec<usize>> = (0..c.n_kv_heads)
            .map(|_| rng.choose_distinct(c.n_pairs(), 3))
            .collect();
        let wq_t = absorb_bk_into_wq(&c, &wq, &idx);
        assert_eq!(wq_t.dims2(), (c.d_model, c.n_heads * 6));
        // Query heads 0,1 share kv head 0's indices; 2,3 share kv head 1's.
        let plan = LayerPlan::new(&c, idx.clone());
        assert_eq!(plan.q_table.theta_sel[0], plan.k_table.theta_sel[0]);
        assert_eq!(plan.q_table.theta_sel[1], plan.k_table.theta_sel[0]);
        assert_eq!(plan.q_table.theta_sel[2], plan.k_table.theta_sel[1]);
    }
}
