//! Serving workload generation: Poisson arrivals, Zipf-ish prompt lengths
//! drawn from the corpus, configurable generation lengths.  Deterministic
//! under a seed so benches are reproducible.

use crate::coordinator::Request;
use crate::util::rng::Rng;

#[derive(Debug, Clone)]
pub struct WorkloadConfig {
    pub n_requests: usize,
    /// Mean arrival rate (requests/s) for the Poisson process.
    pub arrival_rate: f64,
    /// Prompt length choices (weighted towards the prefill buckets so the
    /// bucketed prefill path is exercised).
    pub prompt_lens: Vec<usize>,
    pub min_new: usize,
    pub max_new: usize,
    pub seed: u64,
}

impl Default for WorkloadConfig {
    fn default() -> Self {
        WorkloadConfig {
            n_requests: 32,
            arrival_rate: 20.0,
            prompt_lens: vec![16, 32, 32, 64, 128],
            min_new: 8,
            max_new: 48,
            seed: 42,
        }
    }
}

/// A request plus its arrival offset from t=0.
#[derive(Debug, Clone)]
pub struct TimedRequest {
    pub at_secs: f64,
    pub request: Request,
}

/// Draw a workload trace: prompts are real corpus slices (so generation is
/// in-distribution), arrivals are Poisson.
pub fn generate(cfg: &WorkloadConfig, corpus: &[u8]) -> Vec<TimedRequest> {
    let mut rng = Rng::new(cfg.seed);
    let mut t = 0.0f64;
    let mut out = Vec::with_capacity(cfg.n_requests);
    for id in 0..cfg.n_requests {
        t += rng.exp(cfg.arrival_rate);
        let plen = cfg.prompt_lens[rng.below(cfg.prompt_lens.len())];
        let start = rng.below(corpus.len().saturating_sub(plen + 1).max(1));
        let prompt = corpus[start..start + plen].to_vec();
        let max_new = rng.range(cfg.min_new, cfg.max_new + 1);
        out.push(TimedRequest {
            at_secs: t,
            request: Request::new(id as u64, prompt, max_new),
        });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn corpus() -> Vec<u8> {
        (0..10_000).map(|i| (i % 251) as u8).collect()
    }

    #[test]
    fn deterministic() {
        let cfg = WorkloadConfig::default();
        let a = generate(&cfg, &corpus());
        let b = generate(&cfg, &corpus());
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.request.prompt, y.request.prompt);
            assert!((x.at_secs - y.at_secs).abs() < 1e-12);
        }
    }

    #[test]
    fn arrivals_monotone_and_rate_sane() {
        let cfg = WorkloadConfig {
            n_requests: 200,
            arrival_rate: 50.0,
            ..Default::default()
        };
        let w = generate(&cfg, &corpus());
        for pair in w.windows(2) {
            assert!(pair[0].at_secs <= pair[1].at_secs);
        }
        let span = w.last().unwrap().at_secs;
        let rate = 200.0 / span;
        assert!((rate - 50.0).abs() < 15.0, "empirical rate {rate}");
    }

    #[test]
    fn prompt_lengths_from_menu() {
        let cfg = WorkloadConfig::default();
        let w = generate(&cfg, &corpus());
        for r in &w {
            assert!(cfg.prompt_lens.contains(&r.request.prompt.len()));
            assert!(r.request.max_new >= cfg.min_new && r.request.max_new <= cfg.max_new);
        }
    }
}
