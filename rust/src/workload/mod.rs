//! Serving workload generation: Poisson arrivals, Zipf-ish prompt lengths
//! drawn from the corpus, configurable generation lengths.  Deterministic
//! under a seed so benches are reproducible.

use crate::coordinator::Request;
use crate::util::rng::Rng;

#[derive(Debug, Clone)]
pub struct WorkloadConfig {
    pub n_requests: usize,
    /// Mean arrival rate (requests/s) for the Poisson process.
    pub arrival_rate: f64,
    /// Prompt length choices (weighted towards the prefill buckets so the
    /// bucketed prefill path is exercised).
    pub prompt_lens: Vec<usize>,
    pub min_new: usize,
    pub max_new: usize,
    pub seed: u64,
}

impl Default for WorkloadConfig {
    fn default() -> Self {
        WorkloadConfig {
            n_requests: 32,
            arrival_rate: 20.0,
            prompt_lens: vec![16, 32, 32, 64, 128],
            min_new: 8,
            max_new: 48,
            seed: 42,
        }
    }
}

/// A request plus its arrival offset from t=0.
#[derive(Debug, Clone)]
pub struct TimedRequest {
    pub at_secs: f64,
    pub request: Request,
}

/// Draw a workload trace: prompts are real corpus slices (so generation is
/// in-distribution), arrivals are Poisson.
pub fn generate(cfg: &WorkloadConfig, corpus: &[u8]) -> Vec<TimedRequest> {
    let mut rng = Rng::new(cfg.seed);
    let mut t = 0.0f64;
    let mut out = Vec::with_capacity(cfg.n_requests);
    for id in 0..cfg.n_requests {
        t += rng.exp(cfg.arrival_rate);
        let plen = cfg.prompt_lens[rng.below(cfg.prompt_lens.len())];
        let start = rng.below(corpus.len().saturating_sub(plen + 1).max(1));
        let prompt = corpus[start..start + plen].to_vec();
        let max_new = rng.range(cfg.min_new, cfg.max_new + 1);
        out.push(TimedRequest {
            at_secs: t,
            request: Request::new(id as u64, prompt, max_new),
        });
    }
    out
}

/// The token value planted at needle positions.  Filler is drawn from
/// `[0, NEEDLE_TOKEN)`, so a needle can never be confused with filler and
/// recall over a pressed cache is unambiguous.
pub const NEEDLE_TOKEN: u8 = 250;

#[derive(Debug, Clone)]
pub struct NeedleConfig {
    /// Total prompt length (filler + needles).
    pub total_len: usize,
    /// How many recall tokens to plant.
    pub n_needles: usize,
    /// Needles land in `[margin, total_len - margin)` so they are neither
    /// trivially protected by a press's head pin nor by its recency tail.
    pub margin: usize,
    pub seed: u64,
}

impl Default for NeedleConfig {
    fn default() -> Self {
        NeedleConfig {
            total_len: 1024,
            n_needles: 16,
            margin: 64,
            seed: 7,
        }
    }
}

/// A needle-in-a-haystack prompt: seeded filler with `NEEDLE_TOKEN`
/// planted at known, sorted positions.
#[derive(Debug, Clone)]
pub struct NeedlePrompt {
    pub prompt: Vec<u8>,
    /// Sorted logical positions of the planted needles.
    pub positions: Vec<usize>,
}

impl NeedlePrompt {
    /// Fraction of planted needles whose logical positions appear in
    /// `survivors` (a session's post-press `row_positions`).  1.0 for a
    /// retain-all cache by construction.
    pub fn recall(&self, survivors: &[u32]) -> f64 {
        if self.positions.is_empty() {
            return 1.0;
        }
        let hit = self
            .positions
            .iter()
            .filter(|&&p| survivors.binary_search(&(p as u32)).is_ok())
            .count();
        hit as f64 / self.positions.len() as f64
    }
}

/// Build a deterministic needle prompt: filler in `[0, NEEDLE_TOKEN)`,
/// needles at `n_needles` distinct seeded positions inside the margins.
pub fn generate_needles(cfg: &NeedleConfig) -> NeedlePrompt {
    assert!(cfg.total_len > 2 * cfg.margin, "margins leave no interior");
    let mut rng = Rng::new(cfg.seed);
    let mut prompt: Vec<u8> = (0..cfg.total_len)
        .map(|_| rng.below(NEEDLE_TOKEN as usize) as u8)
        .collect();
    let interior = cfg.total_len - 2 * cfg.margin;
    let positions: Vec<usize> = rng
        .choose_distinct(interior, cfg.n_needles.min(interior))
        .into_iter()
        .map(|p| p + cfg.margin)
        .collect();
    for &p in &positions {
        prompt[p] = NEEDLE_TOKEN;
    }
    NeedlePrompt { prompt, positions }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn corpus() -> Vec<u8> {
        (0..10_000).map(|i| (i % 251) as u8).collect()
    }

    #[test]
    fn deterministic() {
        let cfg = WorkloadConfig::default();
        let a = generate(&cfg, &corpus());
        let b = generate(&cfg, &corpus());
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.request.prompt, y.request.prompt);
            assert!((x.at_secs - y.at_secs).abs() < 1e-12);
        }
    }

    #[test]
    fn arrivals_monotone_and_rate_sane() {
        let cfg = WorkloadConfig {
            n_requests: 200,
            arrival_rate: 50.0,
            ..Default::default()
        };
        let w = generate(&cfg, &corpus());
        for pair in w.windows(2) {
            assert!(pair[0].at_secs <= pair[1].at_secs);
        }
        let span = w.last().unwrap().at_secs;
        let rate = 200.0 / span;
        assert!((rate - 50.0).abs() < 15.0, "empirical rate {rate}");
    }

    #[test]
    fn needles_are_deterministic_and_unambiguous() {
        let cfg = NeedleConfig::default();
        let a = generate_needles(&cfg);
        let b = generate_needles(&cfg);
        assert_eq!(a.prompt, b.prompt);
        assert_eq!(a.positions, b.positions);
        assert_eq!(a.positions.len(), cfg.n_needles);
        for w in a.positions.windows(2) {
            assert!(w[0] < w[1]);
        }
        for (i, &t) in a.prompt.iter().enumerate() {
            if a.positions.binary_search(&i).is_ok() {
                assert_eq!(t, NEEDLE_TOKEN);
            } else {
                assert!(t < NEEDLE_TOKEN, "filler at {i} collides with the needle token");
            }
            if t == NEEDLE_TOKEN {
                assert!(
                    (cfg.margin..cfg.total_len - cfg.margin).contains(&i),
                    "needle at {i} outside the margins"
                );
            }
        }
    }

    #[test]
    fn recall_counts_surviving_positions() {
        let cfg = NeedleConfig {
            total_len: 256,
            n_needles: 8,
            margin: 16,
            seed: 3,
        };
        let np = generate_needles(&cfg);
        let all: Vec<u32> = (0..256).collect();
        assert_eq!(np.recall(&all), 1.0);
        assert_eq!(np.recall(&[]), 0.0);
        // Keep exactly half the needles: recall is exactly 0.5.
        let half: Vec<u32> = np.positions[..4].iter().map(|&p| p as u32).collect();
        assert_eq!(np.recall(&half), 0.5);
    }

    #[test]
    fn prompt_lengths_from_menu() {
        let cfg = WorkloadConfig::default();
        let w = generate(&cfg, &corpus());
        for r in &w {
            assert!(cfg.prompt_lens.contains(&r.request.prompt.len()));
            assert!(r.request.max_new >= cfg.min_new && r.request.max_new <= cfg.max_new);
        }
    }
}
