//! Token-level retention presses over the paged KV cache.
//!
//! A *press* (terminology from the kvpress line of work) decides which
//! token rows of a session's cache survive as the context grows; the
//! cache then compacts the survivors in place
//! ([`super::PagedKvCache::apply_retention`]), keeping their original
//! RoPE positions so attention scores are computed over the true
//! logical positions.  Four policies:
//!
//! * [`Press::Window`] — keep the most recent rows (sliding window with
//!   the shared-prefix rows pinned).
//! * [`Press::L2Norm`] — keep rows whose keys have the *lowest* L2 norm
//!   (low-norm keys attract attention mass; Devoto et al.).
//! * [`Press::AttnScore`] — keep rows with the highest cumulative
//!   post-softmax attention mass, fed from the engine's decode pass.
//! * [`Press::AnchorReservoir`] — keep the leading anchor rows, the
//!   recency window, and a seeded uniform reservoir of the middle.
//!
//! Every plan honours three hard floors regardless of policy: protected
//! rows (shared prefix blocks and pending copy-on-write destinations)
//! survive *in place*, unwritten rows (mid-prefill) survive, and the
//! most recent [`RECENT_TOKENS`] written rows survive.  Budgets below
//! [`MIN_TOKENS`] never press at all, which is what keeps short-context
//! workloads (and the whole tier-1 suite) untouched even when a policy
//! is forced on globally via `RAP_RETENTION`.

use crate::util::rng::Rng;

/// Contexts at or below this many resident rows are never pressed.
pub const MIN_TOKENS: usize = 512;

/// A press fires only once the resident rows exceed the budget by this
/// slack — hysteresis that amortises the O(rows) compaction.
pub const SLACK_TOKENS: usize = 128;

/// The most recent written rows are always retained (the local window
/// every policy needs for coherent next-token prediction).
pub const RECENT_TOKENS: usize = super::BLOCK_TOKENS * 4;

/// Leading rows the `AnchorReservoir` press pins (attention-sink
/// anchors), beyond whatever the protected prefix already pins.
pub const ANCHOR_TOKENS: usize = super::BLOCK_TOKENS * 4;

/// Retention policy for one session.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Press {
    /// Keep the most recent rows.
    Window,
    /// Keep rows with the lowest key L2 norm.
    L2Norm,
    /// Keep rows with the highest cumulative attention mass.
    AttnScore,
    /// Anchors + recency window + seeded reservoir of the middle.
    AnchorReservoir,
}

impl Press {
    /// Parse the wire/env name (`window`, `l2norm`, `attn-score`,
    /// `anchor-reservoir`).
    pub fn parse(name: &str) -> Option<Press> {
        match name {
            "window" => Some(Press::Window),
            "l2norm" => Some(Press::L2Norm),
            "attn-score" => Some(Press::AttnScore),
            "anchor-reservoir" => Some(Press::AnchorReservoir),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Press::Window => "window",
            Press::L2Norm => "l2norm",
            Press::AttnScore => "attn-score",
            Press::AnchorReservoir => "anchor-reservoir",
        }
    }

    /// Presses that need no engine-fed score stream can run mid-prefill;
    /// `AttnScore` has nothing to rank by until decode feeds it.
    pub fn works_during_prefill(&self) -> bool {
        !matches!(self, Press::AttnScore)
    }
}

/// Per-request retention policy: retain `ratio` of the logical context
/// (clamped below by [`MIN_TOKENS`]) under `press`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct RetentionSpec {
    pub press: Press,
    /// Fraction of the logical context retained, in (0, 1].
    pub ratio: f32,
}

impl RetentionSpec {
    /// Parse `"<policy>:<ratio>"` (e.g. `window:0.5`).  A bare policy
    /// name defaults to ratio 0.5.
    pub fn parse(s: &str) -> Option<RetentionSpec> {
        let (name, ratio) = match s.split_once(':') {
            Some((n, r)) => (n, r.parse::<f32>().ok()?),
            None => (s, 0.5),
        };
        if !ratio.is_finite() || ratio <= 0.0 || ratio > 1.0 {
            return None;
        }
        Some(RetentionSpec { press: Press::parse(name)?, ratio })
    }

    /// Default policy from the `RAP_RETENTION` environment variable
    /// (`None` when unset or unparsable — retain-all).
    pub fn from_env() -> Option<RetentionSpec> {
        std::env::var("RAP_RETENTION").ok().as_deref().and_then(RetentionSpec::parse)
    }

    /// Row budget for a context of `logical_len` positions.
    pub fn budget(&self, logical_len: usize) -> usize {
        (((logical_len as f64) * self.ratio as f64).ceil() as usize).max(MIN_TOKENS)
    }
}

/// Everything a press plan needs about one session, read-only.
pub struct PressInputs<'a> {
    /// Physical rows currently resident.
    pub rows: usize,
    /// Rows `[0, written_rows)` hold written K/V; the tail is unwritten
    /// (mid-prefill) and must survive untouched.
    pub written_rows: usize,
    /// Rows `[0, protected_rows)` must survive in place (shared blocks).
    pub protected_rows: usize,
    /// Logical context length (drives the budget).
    pub logical_len: usize,
    /// Logical position per row (`None` = identity).
    pub positions: Option<&'a [u32]>,
    /// Cumulative attention mass per row (empty unless tracked).
    pub scores: &'a [f32],
    /// Squared key L2 norm per row (empty unless the policy needs it).
    pub key_norms: &'a [f32],
    /// Session id — seeds the reservoir press deterministically.
    pub session: u64,
}

/// Cheap pre-check: would a press over this session evict anything?
/// Lets the cache skip norm computation and planning entirely.
pub fn press_due(spec: &RetentionSpec, rows: usize, logical_len: usize) -> bool {
    rows > spec.budget(logical_len) + SLACK_TOKENS
}

/// Plan the keep set (ascending physical rows) for one press, or `None`
/// when nothing would be evicted.  The plan always satisfies the
/// [`super::PagedKvCache::apply_retention`] contract: ascending, within
/// range, protected prefix identical.
pub fn plan_keep(spec: &RetentionSpec, inp: &PressInputs) -> Option<Vec<usize>> {
    let rows = inp.rows;
    let budget = spec.budget(inp.logical_len);
    if rows <= budget + SLACK_TOKENS {
        return None;
    }
    let written = inp.written_rows.min(rows);
    let recent_floor = written.saturating_sub(RECENT_TOKENS).max(inp.protected_rows);
    // Forced rows: protected prefix, recency window, unwritten tail.
    // Candidates (evictable): written rows between the two.
    let forced_head = inp.protected_rows;
    let forced_tail = rows - recent_floor;
    let forced = forced_head + forced_tail;
    let candidates: Vec<usize> = (forced_head..recent_floor).collect();
    if candidates.is_empty() {
        return None;
    }
    let n_choose = budget.saturating_sub(forced).min(candidates.len());
    if n_choose == candidates.len() {
        return None;
    }
    let mut chosen: Vec<usize> = match spec.press {
        Press::Window => {
            // Most recent candidates win.
            candidates[candidates.len() - n_choose..].to_vec()
        }
        Press::L2Norm => {
            // Lowest squared key norm wins; ties resolve to recency.
            let mut order = candidates.clone();
            order.sort_by(|&a, &b| {
                let (na, nb) = (inp.key_norms[a], inp.key_norms[b]);
                na.partial_cmp(&nb).unwrap_or(std::cmp::Ordering::Equal).then(b.cmp(&a))
            });
            order.truncate(n_choose);
            order
        }
        Press::AttnScore => {
            // Highest cumulative attention mass wins; ties to recency.
            let score = |r: usize| inp.scores.get(r).copied().unwrap_or(0.0);
            let mut order = candidates.clone();
            order.sort_by(|&a, &b| {
                score(b)
                    .partial_cmp(&score(a))
                    .unwrap_or(std::cmp::Ordering::Equal)
                    .then(b.cmp(&a))
            });
            order.truncate(n_choose);
            order
        }
        Press::AnchorReservoir => {
            let anchors = ANCHOR_TOKENS.min(n_choose).min(candidates.len());
            let mut keep: Vec<usize> = candidates[..anchors].to_vec();
            let middle = &candidates[anchors..];
            let want = n_choose - anchors;
            if want >= middle.len() {
                keep.extend_from_slice(middle);
            } else if want > 0 {
                // Algorithm R, seeded from (session, logical_len): stable
                // within a press, fresh across context growth.
                let mut rng =
                    Rng::new(inp.session ^ (inp.logical_len as u64).wrapping_mul(0x9E37));
                let mut res: Vec<usize> = middle[..want].to_vec();
                for (i, &r) in middle.iter().enumerate().skip(want) {
                    let j = rng.below(i + 1);
                    if j < want {
                        res[j] = r;
                    }
                }
                keep.extend_from_slice(&res);
            }
            keep
        }
    };
    chosen.sort_unstable();
    let mut keep = Vec::with_capacity(forced + chosen.len());
    keep.extend(0..forced_head);
    keep.extend(chosen);
    keep.extend(recent_floor..rows);
    debug_assert!(keep.windows(2).all(|w| w[0] < w[1]));
    if keep.len() == rows {
        return None;
    }
    Some(keep)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn inputs(rows: usize) -> PressInputs<'static> {
        PressInputs {
            rows,
            written_rows: rows,
            protected_rows: 0,
            logical_len: rows,
            positions: None,
            scores: &[],
            key_norms: &[],
            session: 7,
        }
    }

    #[test]
    fn parse_specs() {
        let s = RetentionSpec::parse("window:0.5").unwrap();
        assert_eq!(s.press, Press::Window);
        assert_eq!(s.ratio, 0.5);
        assert_eq!(RetentionSpec::parse("anchor-reservoir").unwrap().ratio, 0.5);
        assert!(RetentionSpec::parse("window:0.0").is_none());
        assert!(RetentionSpec::parse("window:1.5").is_none());
        assert!(RetentionSpec::parse("window:nan").is_none());
        assert!(RetentionSpec::parse("bogus:0.5").is_none());
    }

    #[test]
    fn short_contexts_are_never_pressed() {
        let spec = RetentionSpec { press: Press::Window, ratio: 0.1 };
        assert!(plan_keep(&spec, &inputs(MIN_TOKENS)).is_none());
        assert!(plan_keep(&spec, &inputs(MIN_TOKENS + SLACK_TOKENS)).is_none());
    }

    #[test]
    fn window_keeps_recent_and_respects_budget() {
        let spec = RetentionSpec { press: Press::Window, ratio: 0.25 };
        let rows = 4096;
        let keep = plan_keep(&spec, &inputs(rows)).unwrap();
        assert_eq!(keep.len(), spec.budget(rows));
        // The tail is intact.
        assert!(keep.ends_with(&[rows - 2, rows - 1]));
        assert!(keep.windows(2).all(|w| w[0] < w[1]));
    }

    #[test]
    fn protected_and_unwritten_rows_always_survive() {
        let spec = RetentionSpec { press: Press::Window, ratio: 0.25 };
        let mut inp = inputs(4096);
        inp.protected_rows = 48;
        inp.written_rows = 3000;
        let keep = plan_keep(&spec, &inp).unwrap();
        for j in 0..48 {
            assert_eq!(keep[j], j);
        }
        // Every unwritten row survives.
        assert!((3000..4096).all(|r| keep.binary_search(&r).is_ok()));
    }

    #[test]
    fn l2norm_prefers_low_norm_rows() {
        let spec = RetentionSpec { press: Press::L2Norm, ratio: 0.25 };
        let rows = 2048;
        let norms: Vec<f32> = (0..rows).map(|r| if r % 2 == 0 { 0.1 } else { 9.0 }).collect();
        let mut inp = inputs(rows);
        inp.key_norms = &norms;
        let keep = plan_keep(&spec, &inp).unwrap();
        let evictable_end = rows - RECENT_TOKENS;
        let kept_mid: Vec<usize> =
            keep.iter().copied().filter(|&r| r < evictable_end).collect();
        assert!(kept_mid.iter().all(|&r| r % 2 == 0), "only low-norm rows kept");
    }

    #[test]
    fn attn_score_keeps_heavy_rows() {
        let spec = RetentionSpec { press: Press::AttnScore, ratio: 0.25 };
        let rows = 2048;
        let scores: Vec<f32> = (0..rows).map(|r| if r < 100 { 5.0 } else { 0.0 }).collect();
        let mut inp = inputs(rows);
        inp.scores = &scores;
        let keep = plan_keep(&spec, &inp).unwrap();
        assert!((0..100).all(|r| keep.binary_search(&r).is_ok()));
    }

    #[test]
    fn anchor_reservoir_is_deterministic() {
        let spec = RetentionSpec { press: Press::AnchorReservoir, ratio: 0.25 };
        let a = plan_keep(&spec, &inputs(4096)).unwrap();
        let b = plan_keep(&spec, &inputs(4096)).unwrap();
        assert_eq!(a, b);
        // Anchors survive.
        assert!((0..ANCHOR_TOKENS).all(|r| a.binary_search(&r).is_ok()));
    }
}
