//! Latent-width-aware paged KV-cache: block allocator **and** backing store.
//!
//! The serving-side resource RAP compresses.  Sessions allocate cache space
//! in fixed-size token *blocks*; each layer's block holds
//! `n_kv_heads * block_tokens * (k_width + v_width)` floats, where the
//! widths come from the variant's pruning plan — so the *same allocator*
//! serves baseline and compressed models and its accounting directly
//! exhibits the paper's KV-cache reduction.
//!
//! Two construction modes:
//!
//! * [`PagedKvCache::new`] — accounting-only.  The coordinator uses this
//!   over backends that own their KV state elsewhere (PJRT keeps host
//!   literals per session); only block bookkeeping and backpressure run
//!   here.
//! * [`PagedKvCache::with_storage`] — the allocator also owns the latent
//!   K/V floats, one [`LayerStore`] per layer laid out block-major:
//!   `[block][kv_head][token_in_block][width]`.  The pure-Rust engine reads
//!   and writes rows *through the page table* ([`PagedSeqLayer`]), so a
//!   session's cache is physically scattered across blocks exactly like a
//!   vLLM-style paged cache, while each (block, head) run of
//!   `BLOCK_TOKENS` rows stays contiguous for the blocked attention
//!   kernels (`tensor::ops::dot_rows_scaled` / `axpy_rows`).
//!
//! Freshly allocated blocks are zeroed at `reserve` time, so block reuse
//! after [`PagedKvCache::release`] can never leak one session's K/V rows
//! into another session — covered by the `no_stale_rows_across_reuse` test.
//!
//! The engine-facing read/write abstraction is [`KvLayerView`]; the dense
//! per-sequence `model::LayerCache` implements the same trait, which is how
//! paged and dense decode stay bit-identical (one set of kernels, two
//! layouts).
//!
//! `quant` adds int4 group quantization of latent rows (the Fig. 12
//! orthogonality experiment: RAP + 4-bit KV).

pub mod quant;

use std::collections::BTreeMap;
use std::marker::PhantomData;

use anyhow::{bail, Result};

use crate::config::{ModelConfig, VariantSpec};

pub const BLOCK_TOKENS: usize = 16;

/// Static description of one variant's per-layer cache widths.
#[derive(Debug, Clone)]
pub struct CacheShape {
    pub n_layers: usize,
    pub n_kv_heads: usize,
    pub k_width: Vec<usize>,
    pub v_width: Vec<usize>,
}

impl CacheShape {
    pub fn of(cfg: &ModelConfig, spec: &VariantSpec) -> CacheShape {
        CacheShape {
            n_layers: cfg.n_layers,
            n_kv_heads: cfg.n_kv_heads,
            k_width: spec.k_rank.clone(),
            v_width: spec.v_rank.clone(),
        }
    }

    /// f32 count per cached token across all layers/heads.
    pub fn floats_per_token(&self) -> usize {
        self.n_kv_heads
            * (self.k_width.iter().sum::<usize>() + self.v_width.iter().sum::<usize>())
    }

    /// f32 count per cached token for one layer (all KV heads).
    pub fn layer_floats_per_token(&self, layer: usize) -> usize {
        self.n_kv_heads * (self.k_width[layer] + self.v_width[layer])
    }

    pub fn bytes_per_token(&self) -> usize {
        4 * self.floats_per_token()
    }

    /// Resident bytes for `tokens` cached tokens — the single source of
    /// truth for both engine-side (`model::Cache::bytes_used`) and
    /// allocator-side accounting, so the two can never diverge.
    pub fn bytes_for_tokens(&self, tokens: usize) -> usize {
        self.bytes_per_token() * tokens
    }

    pub fn bytes_per_block(&self) -> usize {
        self.bytes_per_token() * BLOCK_TOKENS
    }
}

/// One layer's latent K/V backing store, sized for the whole block budget.
///
/// Layout (both K and V): `[block][kv_head][token_in_block][width]` — a
/// (block, head) pair owns one contiguous run of `BLOCK_TOKENS * width`
/// floats, which is the unit the blocked attention kernels consume.
///
/// Base pointers are captured once at construction (the buffers are never
/// resized) so the batched decode path can hand disjoint-session writers
/// raw row slices without re-borrowing the whole store — same idiom as the
/// matmul kernel's `OutPtr`.
#[derive(Debug)]
pub struct LayerStore {
    k: Vec<f32>,
    v: Vec<f32>,
    k_ptr: *mut f32,
    v_ptr: *mut f32,
    k_width: usize,
    v_width: usize,
}

// SAFETY: the raw pointers alias only `self.k` / `self.v`, and every write
// path goes through `PagedSeqLayer`, whose users hold disjoint blocks
// (enforced by the allocator's free-list: a block id is owned by at most
// one session).
unsafe impl Send for LayerStore {}
unsafe impl Sync for LayerStore {}

impl LayerStore {
    fn new(capacity_blocks: usize, n_kv_heads: usize, k_width: usize, v_width: usize) -> LayerStore {
        let mut k = vec![0.0f32; capacity_blocks * n_kv_heads * BLOCK_TOKENS * k_width];
        let mut v = vec![0.0f32; capacity_blocks * n_kv_heads * BLOCK_TOKENS * v_width];
        let (k_ptr, v_ptr) = (k.as_mut_ptr(), v.as_mut_ptr());
        LayerStore { k, v, k_ptr, v_ptr, k_width, v_width }
    }

    fn zero_block(&mut self, block: usize, n_kv_heads: usize) {
        let kn = n_kv_heads * BLOCK_TOKENS * self.k_width;
        let vn = n_kv_heads * BLOCK_TOKENS * self.v_width;
        self.k[block * kn..(block + 1) * kn].fill(0.0);
        self.v[block * vn..(block + 1) * vn].fill(0.0);
    }
}

/// Read/write access to one sequence's latent K/V rows for one layer.
///
/// Implemented by the dense per-sequence `model::LayerCache` and by the
/// paged [`PagedSeqLayer`]; the engine's projection/attention kernels are
/// generic over this trait, so both layouts execute identical arithmetic.
pub trait KvLayerView {
    fn k_row(&self, head: usize, t: usize) -> &[f32];
    fn v_row(&self, head: usize, t: usize) -> &[f32];
    fn k_row_mut(&mut self, head: usize, t: usize) -> &mut [f32];
    fn v_row_mut(&mut self, head: usize, t: usize) -> &mut [f32];
    /// Visit the contiguous runs of K rows covering tokens `[0, s)` of
    /// `head`, in ascending token order.  The callback receives the first
    /// token index of the run and a slice of `run_len * k_width` floats.
    fn for_k_runs<F: FnMut(usize, &[f32])>(&self, head: usize, s: usize, f: F);
    /// Same for V rows.
    fn for_v_runs<F: FnMut(usize, &[f32])>(&self, head: usize, s: usize, f: F);
    /// Visit the contiguous runs of K rows covering tokens `[t0, t0 + n)`
    /// of `head` *mutably*, in ascending token order — the chunked-prefill
    /// write path: one callback per run instead of one row lookup per
    /// token.  The callback receives the first token index of the run and
    /// a mutable slice of `run_len * k_width` floats.
    fn for_k_runs_mut<F: FnMut(usize, &mut [f32])>(&mut self, head: usize, t0: usize, n: usize, f: F);
    /// Same for V rows.
    fn for_v_runs_mut<F: FnMut(usize, &mut [f32])>(&mut self, head: usize, t0: usize, n: usize, f: F);
}

/// One session × one layer window into the paged store: rows are addressed
/// through the session's page table, runs are per-block contiguous.
///
/// Constructed via [`StorePtrs::seq_layer`].  Writers for different
/// sessions may exist concurrently (batched decode parallelises across
/// sessions); the allocator guarantees their block sets are disjoint.
pub struct PagedSeqLayer<'a> {
    k_base: *mut f32,
    v_base: *mut f32,
    blocks: &'a [usize],
    n_kv_heads: usize,
    k_width: usize,
    v_width: usize,
}

// SAFETY: see `LayerStore` — disjoint blocks per session.
unsafe impl Send for PagedSeqLayer<'_> {}
// SAFETY: every `&self` method only reads; mutation requires `&mut self`,
// which Rust's borrow rules keep exclusive.  Sharing a view across the
// chunked-prefill attention workers (read-only score/context sweeps) is
// therefore sound — the chunk's K/V rows are fully written before the
// shared borrow is taken.
unsafe impl Sync for PagedSeqLayer<'_> {}

impl PagedSeqLayer<'_> {
    #[inline]
    fn k_off(&self, head: usize, t: usize) -> usize {
        let (block, slot) = (self.blocks[t / BLOCK_TOKENS], t % BLOCK_TOKENS);
        ((block * self.n_kv_heads + head) * BLOCK_TOKENS + slot) * self.k_width
    }

    #[inline]
    fn v_off(&self, head: usize, t: usize) -> usize {
        let (block, slot) = (self.blocks[t / BLOCK_TOKENS], t % BLOCK_TOKENS);
        ((block * self.n_kv_heads + head) * BLOCK_TOKENS + slot) * self.v_width
    }
}

impl KvLayerView for PagedSeqLayer<'_> {
    #[inline]
    fn k_row(&self, head: usize, t: usize) -> &[f32] {
        unsafe { std::slice::from_raw_parts(self.k_base.add(self.k_off(head, t)), self.k_width) }
    }

    #[inline]
    fn v_row(&self, head: usize, t: usize) -> &[f32] {
        unsafe { std::slice::from_raw_parts(self.v_base.add(self.v_off(head, t)), self.v_width) }
    }

    #[inline]
    fn k_row_mut(&mut self, head: usize, t: usize) -> &mut [f32] {
        unsafe {
            std::slice::from_raw_parts_mut(self.k_base.add(self.k_off(head, t)), self.k_width)
        }
    }

    #[inline]
    fn v_row_mut(&mut self, head: usize, t: usize) -> &mut [f32] {
        unsafe {
            std::slice::from_raw_parts_mut(self.v_base.add(self.v_off(head, t)), self.v_width)
        }
    }

    fn for_k_runs<F: FnMut(usize, &[f32])>(&self, head: usize, s: usize, mut f: F) {
        let mut t0 = 0;
        while t0 < s {
            let run = (s - t0).min(BLOCK_TOKENS);
            let rows = unsafe {
                std::slice::from_raw_parts(
                    self.k_base.add(self.k_off(head, t0)),
                    run * self.k_width,
                )
            };
            f(t0, rows);
            t0 += run;
        }
    }

    fn for_v_runs<F: FnMut(usize, &[f32])>(&self, head: usize, s: usize, mut f: F) {
        let mut t0 = 0;
        while t0 < s {
            let run = (s - t0).min(BLOCK_TOKENS);
            let rows = unsafe {
                std::slice::from_raw_parts(
                    self.v_base.add(self.v_off(head, t0)),
                    run * self.v_width,
                )
            };
            f(t0, rows);
            t0 += run;
        }
    }

    fn for_k_runs_mut<F: FnMut(usize, &mut [f32])>(&mut self, head: usize, t0: usize, n: usize, mut f: F) {
        let (mut t, end) = (t0, t0 + n);
        while t < end {
            // A chunk may start mid-block: the first run ends at the block
            // boundary, later runs are whole blocks (or the chunk tail).
            let run = (end - t).min(BLOCK_TOKENS - t % BLOCK_TOKENS);
            let rows = unsafe {
                std::slice::from_raw_parts_mut(
                    self.k_base.add(self.k_off(head, t)),
                    run * self.k_width,
                )
            };
            f(t, rows);
            t += run;
        }
    }

    fn for_v_runs_mut<F: FnMut(usize, &mut [f32])>(&mut self, head: usize, t0: usize, n: usize, mut f: F) {
        let (mut t, end) = (t0, t0 + n);
        while t < end {
            let run = (end - t).min(BLOCK_TOKENS - t % BLOCK_TOKENS);
            let rows = unsafe {
                std::slice::from_raw_parts_mut(
                    self.v_base.add(self.v_off(head, t)),
                    run * self.v_width,
                )
            };
            f(t, rows);
            t += run;
        }
    }
}

/// Shared read view of the per-session page tables (block id lists).
#[derive(Clone, Copy)]
pub struct PageTables<'a> {
    tables: &'a BTreeMap<u64, SessionAlloc>,
}

impl<'a> PageTables<'a> {
    pub fn blocks(&self, session: u64) -> Option<&'a [usize]> {
        self.tables.get(&session).map(|t| t.blocks.as_slice())
    }

    pub fn tokens(&self, session: u64) -> usize {
        self.tables.get(&session).map(|t| t.tokens).unwrap_or(0)
    }
}

/// Raw per-layer handles into the backing store, witnessed by an exclusive
/// borrow of the owning `PagedKvCache` (so no other reader/writer of the
/// storage exists while these are live).
pub struct StorePtrs<'a> {
    layers: &'a [LayerStore],
    n_kv_heads: usize,
    _excl: PhantomData<&'a mut ()>,
}

// SAFETY: handed to scoped workers that write disjoint sessions' blocks.
unsafe impl Send for StorePtrs<'_> {}
unsafe impl Sync for StorePtrs<'_> {}

impl<'a> StorePtrs<'a> {
    /// View of `session`'s rows in layer `l` (its page table is `blocks`).
    ///
    /// # Safety
    ///
    /// The caller must not let two views over the *same* page table be
    /// written (or written + read) at the same time — that would alias
    /// mutable memory.  Views over *different* sessions are always fine to
    /// use in parallel because the allocator hands each session disjoint
    /// blocks.
    pub unsafe fn seq_layer(&self, l: usize, blocks: &'a [usize]) -> PagedSeqLayer<'a> {
        let ls = &self.layers[l];
        PagedSeqLayer {
            k_base: ls.k_ptr,
            v_base: ls.v_ptr,
            blocks,
            n_kv_heads: self.n_kv_heads,
            k_width: ls.k_width,
            v_width: ls.v_width,
        }
    }
}

/// Paged block allocator with per-session page tables (and, in
/// `with_storage` mode, the latent K/V backing store itself).
///
/// Capacity is expressed in bytes (as an operator would configure it); the
/// block budget adapts to the variant's width, so a RAP-compressed model
/// fits proportionally more tokens in the same budget — the deployability
/// claim of the paper's introduction.
#[derive(Debug)]
pub struct PagedKvCache {
    pub shape: CacheShape,
    capacity_blocks: usize,
    free: Vec<usize>,
    /// session -> block ids (one entry per BLOCK_TOKENS tokens).
    tables: BTreeMap<u64, SessionAlloc>,
    peak_used: usize,
    store: Option<Vec<LayerStore>>,
}

#[derive(Debug, Clone)]
struct SessionAlloc {
    blocks: Vec<usize>,
    tokens: usize,
}

impl PagedKvCache {
    /// Accounting-only allocator (backends that own KV state elsewhere).
    pub fn new(shape: CacheShape, capacity_bytes: usize) -> PagedKvCache {
        let capacity_blocks = capacity_bytes / shape.bytes_per_block().max(1);
        PagedKvCache {
            free: (0..capacity_blocks).rev().collect(),
            tables: BTreeMap::new(),
            peak_used: 0,
            store: None,
            capacity_blocks,
            shape,
        }
    }

    /// Allocator that also owns the latent K/V storage the pure-Rust engine
    /// decodes from.
    pub fn with_storage(shape: CacheShape, capacity_bytes: usize) -> PagedKvCache {
        let mut kv = PagedKvCache::new(shape, capacity_bytes);
        let store = (0..kv.shape.n_layers)
            .map(|l| {
                LayerStore::new(
                    kv.capacity_blocks,
                    kv.shape.n_kv_heads,
                    kv.shape.k_width[l],
                    kv.shape.v_width[l],
                )
            })
            .collect();
        kv.store = Some(store);
        kv
    }

    pub fn has_storage(&self) -> bool {
        self.store.is_some()
    }

    pub fn capacity_blocks(&self) -> usize {
        self.capacity_blocks
    }

    pub fn used_blocks(&self) -> usize {
        self.capacity_blocks - self.free.len()
    }

    pub fn peak_used_blocks(&self) -> usize {
        self.peak_used
    }

    pub fn used_bytes(&self) -> usize {
        self.used_blocks() * self.shape.bytes_per_block()
    }

    /// Max tokens a fresh session could hold right now.
    pub fn free_token_capacity(&self) -> usize {
        self.free.len() * BLOCK_TOKENS
    }

    pub fn session_tokens(&self, session: u64) -> usize {
        self.tables.get(&session).map(|t| t.tokens).unwrap_or(0)
    }

    pub fn sessions(&self) -> usize {
        self.tables.len()
    }

    /// Reserve capacity for `tokens` more tokens of `session`, allocating
    /// (and, with storage, zeroing) blocks as needed.  Fails (backpressure
    /// signal) when out of blocks.
    pub fn reserve(&mut self, session: u64, tokens: usize) -> Result<()> {
        let entry = self
            .tables
            .entry(session)
            .or_insert(SessionAlloc { blocks: Vec::new(), tokens: 0 });
        let needed_tokens = entry.tokens + tokens;
        let needed_blocks = needed_tokens.div_ceil(BLOCK_TOKENS);
        let deficit = needed_blocks.saturating_sub(entry.blocks.len());
        if deficit > self.free.len() {
            bail!(
                "kv-cache exhausted: need {deficit} blocks, {} free (capacity {})",
                self.free.len(),
                self.capacity_blocks
            );
        }
        for _ in 0..deficit {
            let block = self.free.pop().unwrap();
            // Zero recycled blocks so a new session can never observe a
            // previous session's rows (and unwritten positions read as 0).
            if let Some(store) = &mut self.store {
                for ls in store.iter_mut() {
                    ls.zero_block(block, self.shape.n_kv_heads);
                }
            }
            entry.blocks.push(block);
        }
        entry.tokens = needed_tokens;
        self.peak_used = self.peak_used.max(self.capacity_blocks - self.free.len());
        Ok(())
    }

    /// Grow `session`'s reservation so it covers at least `upto` tokens.
    /// No-op when already covered (the coordinator reserves a request's full
    /// budget at admission, making per-step calls free on that path).
    pub fn ensure_tokens(&mut self, session: u64, upto: usize) -> Result<()> {
        let have = self.session_tokens(session);
        if upto > have {
            self.reserve(session, upto - have)
        } else {
            Ok(())
        }
    }

    /// Release a finished session's blocks.
    pub fn release(&mut self, session: u64) {
        if let Some(alloc) = self.tables.remove(&session) {
            self.free.extend(alloc.blocks);
        }
    }

    /// The block ids backing a session (page table), for diagnostics.
    pub fn page_table(&self, session: u64) -> Option<&[usize]> {
        self.tables.get(&session).map(|t| t.blocks.as_slice())
    }

    /// Split into the page-table read view and the raw storage handles the
    /// engine decodes through.  Errors on an accounting-only cache.
    ///
    /// Taking `&mut self` makes the returned handles the only live access
    /// path to the storage; per-session write disjointness is then
    /// guaranteed by block ownership (see [`StorePtrs::seq_layer`]).
    pub fn tables_and_ptrs(&mut self) -> Result<(PageTables<'_>, StorePtrs<'_>)> {
        let Some(store) = &self.store else {
            bail!("PagedKvCache was built accounting-only (use with_storage for engine decode)")
        };
        Ok((
            PageTables { tables: &self.tables },
            StorePtrs {
                layers: store.as_slice(),
                n_kv_heads: self.shape.n_kv_heads,
                _excl: PhantomData,
            },
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn shape(k: usize, v: usize) -> CacheShape {
        CacheShape {
            n_layers: 4,
            n_kv_heads: 2,
            k_width: vec![k; 4],
            v_width: vec![v; 4],
        }
    }

    #[test]
    fn bytes_accounting() {
        let s = shape(24, 24);
        // 2 heads * (24+24) * 4 layers = 384 floats/token
        assert_eq!(s.floats_per_token(), 384);
        assert_eq!(s.bytes_per_token(), 1536);
        assert_eq!(s.bytes_per_block(), 1536 * BLOCK_TOKENS);
        assert_eq!(s.bytes_for_tokens(10), 15360);
        assert_eq!(s.layer_floats_per_token(0), 96);
    }

    #[test]
    fn compressed_fits_proportionally_more() {
        // The deployability claim: at rho=30% the same byte budget holds
        // ~1/0.7x the tokens.
        let budget = 1 << 20;
        let full = PagedKvCache::new(shape(24, 24), budget);
        let rap = PagedKvCache::new(shape(16, 18), budget); // ~70.8% widths
        let gain = rap.free_token_capacity() as f64 / full.free_token_capacity() as f64;
        assert!(gain > 1.3 && gain < 1.55, "gain {gain}");
    }

    #[test]
    fn reserve_release_cycle() {
        let mut c = PagedKvCache::new(shape(8, 8), 1 << 16);
        let cap = c.capacity_blocks();
        assert!(cap > 0);
        c.reserve(1, 20).unwrap(); // 2 blocks
        assert_eq!(c.used_blocks(), 2);
        c.reserve(1, 10).unwrap(); // 30 tokens -> 2 blocks still
        assert_eq!(c.used_blocks(), 2);
        c.reserve(1, 3).unwrap(); // 33 tokens -> 3 blocks
        assert_eq!(c.used_blocks(), 3);
        assert_eq!(c.session_tokens(1), 33);
        c.release(1);
        assert_eq!(c.used_blocks(), 0);
        assert_eq!(c.session_tokens(1), 0);
    }

    #[test]
    fn ensure_tokens_grows_only_the_deficit() {
        let mut c = PagedKvCache::new(shape(8, 8), 1 << 16);
        c.ensure_tokens(1, 20).unwrap();
        assert_eq!(c.session_tokens(1), 20);
        c.ensure_tokens(1, 12).unwrap(); // already covered
        assert_eq!(c.session_tokens(1), 20);
        c.ensure_tokens(1, 40).unwrap();
        assert_eq!(c.session_tokens(1), 40);
        assert_eq!(c.used_blocks(), 3);
    }

    #[test]
    fn exhaustion_is_an_error_not_a_panic() {
        let sh = shape(8, 8);
        let mut c = PagedKvCache::new(sh.clone(), sh.bytes_per_block() * 2);
        assert_eq!(c.capacity_blocks(), 2);
        c.reserve(1, BLOCK_TOKENS * 2).unwrap();
        assert!(c.reserve(2, 1).is_err());
        c.release(1);
        assert!(c.reserve(2, 1).is_ok());
    }

    #[test]
    fn peak_tracking() {
        let sh = shape(8, 8);
        let mut c = PagedKvCache::new(sh.clone(), sh.bytes_per_block() * 8);
        c.reserve(1, BLOCK_TOKENS * 3).unwrap();
        c.release(1);
        c.reserve(2, BLOCK_TOKENS).unwrap();
        assert_eq!(c.peak_used_blocks(), 3);
    }

    #[test]
    fn page_tables_disjoint() {
        let sh = shape(8, 8);
        let mut c = PagedKvCache::new(sh.clone(), sh.bytes_per_block() * 10);
        c.reserve(1, BLOCK_TOKENS * 2).unwrap();
        c.reserve(2, BLOCK_TOKENS * 2).unwrap();
        let t1: Vec<usize> = c.page_table(1).unwrap().to_vec();
        let t2: Vec<usize> = c.page_table(2).unwrap().to_vec();
        assert!(t1.iter().all(|b| !t2.contains(b)));
    }

    #[test]
    fn accounting_only_cache_refuses_storage_access() {
        let mut c = PagedKvCache::new(shape(8, 8), 1 << 16);
        assert!(!c.has_storage());
        assert!(c.tables_and_ptrs().is_err());
    }

    #[test]
    fn storage_rows_round_trip_across_block_boundaries() {
        let sh = shape(6, 4);
        let mut c = PagedKvCache::with_storage(sh.clone(), sh.bytes_per_block() * 8);
        c.reserve(7, BLOCK_TOKENS * 2 + 3).unwrap();
        // Write distinct rows at the block seam: BLOCK_TOKENS-1, BLOCK_TOKENS,
        // BLOCK_TOKENS+1 (plus 0 and the last covered token).
        let probes = [0usize, BLOCK_TOKENS - 1, BLOCK_TOKENS, BLOCK_TOKENS + 1, 2 * BLOCK_TOKENS + 2];
        {
            let (pages, store) = c.tables_and_ptrs().unwrap();
            let blocks = pages.blocks(7).unwrap();
            for l in 0..sh.n_layers {
                // SAFETY: one live view per session at a time.
                let mut view = unsafe { store.seq_layer(l, blocks) };
                for &t in &probes {
                    for hd in 0..sh.n_kv_heads {
                        let tag = (l * 1000 + hd * 100 + t) as f32;
                        for (j, x) in view.k_row_mut(hd, t).iter_mut().enumerate() {
                            *x = tag + j as f32;
                        }
                        for (j, x) in view.v_row_mut(hd, t).iter_mut().enumerate() {
                            *x = -(tag + j as f32);
                        }
                    }
                }
            }
        }
        let (pages, store) = c.tables_and_ptrs().unwrap();
        let blocks = pages.blocks(7).unwrap();
        for l in 0..sh.n_layers {
            let view = unsafe { store.seq_layer(l, blocks) };
            for &t in &probes {
                for hd in 0..sh.n_kv_heads {
                    let tag = (l * 1000 + hd * 100 + t) as f32;
                    let k: Vec<f32> = (0..sh.k_width[l]).map(|j| tag + j as f32).collect();
                    let v: Vec<f32> = (0..sh.v_width[l]).map(|j| -(tag + j as f32)).collect();
                    assert_eq!(view.k_row(hd, t), &k[..], "K l{l} h{hd} t{t}");
                    assert_eq!(view.v_row(hd, t), &v[..], "V l{l} h{hd} t{t}");
                }
            }
        }
    }

    #[test]
    fn runs_cover_rows_in_order_and_match_row_reads() {
        let sh = shape(6, 4);
        let mut c = PagedKvCache::with_storage(sh.clone(), sh.bytes_per_block() * 8);
        let s = BLOCK_TOKENS * 2 + 5;
        c.reserve(3, s).unwrap();
        {
            let (pages, store) = c.tables_and_ptrs().unwrap();
            let mut view = unsafe { store.seq_layer(1, pages.blocks(3).unwrap()) };
            for t in 0..s {
                view.k_row_mut(0, t)[0] = t as f32;
                view.v_row_mut(0, t)[0] = 2.0 * t as f32;
            }
        }
        let (pages, store) = c.tables_and_ptrs().unwrap();
        let view = unsafe { store.seq_layer(1, pages.blocks(3).unwrap()) };
        let mut next = 0usize;
        view.for_k_runs(0, s, |t0, rows| {
            assert_eq!(t0, next);
            let n = rows.len() / sh.k_width[1];
            assert!(n <= BLOCK_TOKENS);
            for (i, chunk) in rows.chunks_exact(sh.k_width[1]).enumerate() {
                assert_eq!(chunk[0], (t0 + i) as f32);
            }
            next += n;
        });
        assert_eq!(next, s);
        let mut seen = 0usize;
        view.for_v_runs(0, s, |t0, rows| {
            for (i, chunk) in rows.chunks_exact(sh.v_width[1]).enumerate() {
                assert_eq!(chunk[0], 2.0 * (t0 + i) as f32);
            }
            seen = t0 + rows.len() / sh.v_width[1];
        });
        assert_eq!(seen, s);
    }

    #[test]
    fn mut_runs_cover_chunks_starting_mid_block() {
        let sh = shape(6, 4);
        let mut c = PagedKvCache::with_storage(sh.clone(), sh.bytes_per_block() * 8);
        let total = BLOCK_TOKENS * 3;
        c.reserve(5, total).unwrap();
        let (pages, store) = c.tables_and_ptrs().unwrap();
        let mut view = unsafe { store.seq_layer(2, pages.blocks(5).unwrap()) };
        // Write a chunk that starts mid-block and crosses two block seams.
        let (t0, n) = (BLOCK_TOKENS - 3, BLOCK_TOKENS + 7);
        let mut starts = Vec::new();
        let mut covered = 0usize;
        view.for_k_runs_mut(0, t0, n, |run_t0, rows| {
            starts.push(run_t0);
            assert_eq!(run_t0, t0 + covered, "runs in ascending token order");
            let w = sh.k_width[2];
            for (i, chunk) in rows.chunks_exact_mut(w).enumerate() {
                chunk[0] = (run_t0 + i) as f32;
            }
            covered += rows.len() / w;
        });
        assert_eq!(covered, n);
        assert_eq!(starts[0], t0);
        // The first run stops at the block boundary.
        assert_eq!(starts[1], BLOCK_TOKENS);
        for t in t0..t0 + n {
            assert_eq!(view.k_row(0, t)[0], t as f32, "row {t} via row read");
        }
        // V visitor: same coverage, disjoint storage.
        let mut seen = 0usize;
        view.for_v_runs_mut(1, t0, n, |run_t0, rows| {
            let w = sh.v_width[2];
            for (i, chunk) in rows.chunks_exact_mut(w).enumerate() {
                chunk[1] = -((run_t0 + i) as f32);
            }
            seen += rows.len() / w;
        });
        assert_eq!(seen, n);
        assert_eq!(view.v_row(1, t0 + n - 1)[1], -((t0 + n - 1) as f32));
    }

    #[test]
    fn no_stale_rows_across_reuse() {
        let sh = shape(5, 5);
        let mut c = PagedKvCache::with_storage(sh.clone(), sh.bytes_per_block() * 2);
        c.reserve(1, BLOCK_TOKENS * 2).unwrap();
        {
            let (pages, store) = c.tables_and_ptrs().unwrap();
            let blocks = pages.blocks(1).unwrap();
            for l in 0..sh.n_layers {
                // SAFETY: one live view per session at a time.
                let mut view = unsafe { store.seq_layer(l, blocks) };
                for t in 0..BLOCK_TOKENS * 2 {
                    for hd in 0..sh.n_kv_heads {
                        view.k_row_mut(hd, t).fill(9.25);
                        view.v_row_mut(hd, t).fill(-9.25);
                    }
                }
            }
        }
        c.release(1);
        // Session 2 must get the same physical blocks back, fully zeroed.
        c.reserve(2, BLOCK_TOKENS * 2).unwrap();
        let (pages, store) = c.tables_and_ptrs().unwrap();
        let blocks = pages.blocks(2).unwrap();
        for l in 0..sh.n_layers {
            let view = unsafe { store.seq_layer(l, blocks) };
            for t in 0..BLOCK_TOKENS * 2 {
                for hd in 0..sh.n_kv_heads {
                    assert!(view.k_row(hd, t).iter().all(|&x| x == 0.0), "stale K l{l} t{t}");
                    assert!(view.v_row(hd, t).iter().all(|&x| x == 0.0), "stale V l{l} t{t}");
                }
            }
        }
    }
}
