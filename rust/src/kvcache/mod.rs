//! Latent-width-aware paged KV-cache: block allocator **and** backing store.
//!
//! The serving-side resource RAP compresses.  Sessions allocate cache space
//! in fixed-size token *blocks*; each layer's block holds
//! `n_kv_heads * block_tokens * (k_width + v_width)` floats, where the
//! widths come from the variant's pruning plan — so the *same allocator*
//! serves baseline and compressed models and its accounting directly
//! exhibits the paper's KV-cache reduction.
//!
//! Two construction modes:
//!
//! * [`PagedKvCache::new`] — accounting-only.  The coordinator uses this
//!   over backends that own their KV state elsewhere (PJRT keeps host
//!   literals per session); only block bookkeeping and backpressure run
//!   here.
//! * [`PagedKvCache::with_storage`] — the allocator also owns the latent
//!   K/V floats, one [`LayerStore`] per layer laid out block-major:
//!   `[block][kv_head][token_in_block][width]`.  The pure-Rust engine reads
//!   and writes rows *through the page table* ([`PagedSeqLayer`]), so a
//!   session's cache is physically scattered across blocks exactly like a
//!   vLLM-style paged cache, while each (block, head) run of
//!   `BLOCK_TOKENS` rows stays contiguous for the blocked attention
//!   kernels (`tensor::ops::dot_rows_scaled` / `axpy_rows`).
//!
//! Blocks are **refcounted**: requests admitted with a prompt prefix
//! already resident (tracked by the [`prefix::PrefixTrie`], keyed on
//! block-aligned token chunks) attach the existing physical blocks
//! read-only instead of allocating and recomputing them
//! ([`PagedKvCache::reserve_prefix`]), and a block returns to the free
//! list only when its *last* reader releases.  A partially matched block
//! is copy-on-write: the session gets a private copy
//! ([`PagedKvCache::materialize_cow`]) before its first prefill write.
//! Write disjointness across concurrent sessions therefore means
//! "refcount == 1 for written blocks" — shared blocks (refcount > 1) are
//! only ever read.
//!
//! Freshly allocated blocks are zeroed at `reserve` time, so block reuse
//! after [`PagedKvCache::release`] can never leak one session's K/V rows
//! into another session — covered by the `no_stale_rows_across_reuse` and
//! `shared_blocks_survive_first_release` tests.
//!
//! **Cold-prefix retention** (opt-in via
//! [`PagedKvCache::retain_cold_prefixes`], used by the serving
//! coordinator): when the last holder of a prefix-trie node releases, the
//! node — and its block — stays resident as a *cold* cache entry instead
//! of being freed, provided its rows were actually written
//! (`SessionAlloc::filled` covers the chunk).  The cache itself takes
//! over the departing session's block refcount (the "cold hold"), so a
//! cold block never reaches the free list by accident; a later admission
//! that matches the chunk revives it for free, and under pressure the
//! allocator evicts cold leaves (LRU age ÷ recompute-cost depth, on a
//! deterministic logical clock) before reporting exhaustion.  Cold
//! blocks are *reclaimable*, so [`PagedKvCache::used_blocks`] counts hot
//! blocks only — a warm cache still reports "all blocks returned" after
//! every session releases.
//!
//! A [`crate::faults::FaultInjector`] can be threaded in via
//! [`PagedKvCache::set_alloc_faults`]: reservations that need new blocks
//! then fail at seeded points with a typed
//! [`crate::faults::InjectedFault`], which the coordinator treats as
//! transient — the hook that lets tests drive eviction/preemption storms
//! deterministically.  Zero-deficit reservations never consult it.
//!
//! The engine-facing read/write abstraction is [`KvLayerView`]; the dense
//! per-sequence `model::LayerCache` implements the same trait, which is how
//! paged and dense decode stay bit-identical (one set of kernels, two
//! layouts).
//!
//! **Logical→physical token indirection** (`retention`): by default a
//! session's cache is the identity map — row `t` holds logical position
//! `t`, and every seed code path runs unchanged (bit-identical).  A
//! retention press ([`PagedKvCache::apply_press`]) may evict token rows
//! mid-flight: surviving rows are compacted in place
//! ([`PagedKvCache::apply_retention`]), fully drained blocks return to the
//! free pool, and the session's `positions` vector records each surviving
//! row's original RoPE position so attention scores stay correct.  The
//! engine reads positions through [`KvLayerView::row_pos`]; `None`
//! positions mean identity and select the exact seed arithmetic.
//!
//! `quant` adds int4 group quantization of latent rows (the Fig. 12
//! orthogonality experiment: RAP + 4-bit KV).

pub mod prefix;
pub mod quant;
pub mod retention;

use std::collections::BTreeMap;
use std::marker::PhantomData;

use anyhow::{bail, Result};

use crate::config::{ModelConfig, VariantSpec};
use crate::faults::FaultInjector;

pub const BLOCK_TOKENS: usize = 16;

/// How a storage-backed cache lays latent rows out in its block buffers.
///
/// `F32` is the default full-precision layout.  `PackedInt4` stores every
/// row as `quant` nibble-packed groups ([`quant::row_bytes`] bytes per
/// row); attention reads the packed bytes directly through the fused
/// kernels ([`quant::dot_rows_scaled_q4`] / [`quant::axpy_rows_q4`]) and
/// f32 rows are never materialized.  The same byte budget therefore holds
/// roughly 6x the blocks (5 bits vs 32 bits per element at `GROUP = 32`).
/// Packed mode supports methods that attend in latent space without
/// reconstruction (Baseline/Rap; guarded at the engine entry points).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum KvStorageMode {
    #[default]
    F32,
    PackedInt4,
}

impl KvStorageMode {
    pub fn name(self) -> &'static str {
        match self {
            KvStorageMode::F32 => "f32",
            KvStorageMode::PackedInt4 => "packed-int4",
        }
    }

    pub fn is_packed(self) -> bool {
        self == KvStorageMode::PackedInt4
    }
}

/// Static description of one variant's per-layer cache widths.
#[derive(Debug, Clone)]
pub struct CacheShape {
    pub n_layers: usize,
    pub n_kv_heads: usize,
    pub k_width: Vec<usize>,
    pub v_width: Vec<usize>,
}

impl CacheShape {
    pub fn of(cfg: &ModelConfig, spec: &VariantSpec) -> CacheShape {
        CacheShape {
            n_layers: cfg.n_layers,
            n_kv_heads: cfg.n_kv_heads,
            k_width: spec.k_rank.clone(),
            v_width: spec.v_rank.clone(),
        }
    }

    /// f32 count per cached token across all layers/heads.
    pub fn floats_per_token(&self) -> usize {
        self.n_kv_heads
            * (self.k_width.iter().sum::<usize>() + self.v_width.iter().sum::<usize>())
    }

    /// f32 count per cached token for one layer (all KV heads).
    pub fn layer_floats_per_token(&self, layer: usize) -> usize {
        self.n_kv_heads * (self.k_width[layer] + self.v_width[layer])
    }

    pub fn bytes_per_token(&self) -> usize {
        4 * self.floats_per_token()
    }

    /// Resident bytes for `tokens` cached tokens — the single source of
    /// truth for both engine-side (`model::Cache::bytes_used`) and
    /// allocator-side accounting, so the two can never diverge.
    pub fn bytes_for_tokens(&self, tokens: usize) -> usize {
        self.bytes_per_token() * tokens
    }

    pub fn bytes_per_block(&self) -> usize {
        self.bytes_per_token() * BLOCK_TOKENS
    }

    /// Bytes per cached token when rows are stored nibble-packed
    /// (`KvStorageMode::PackedInt4`): each row costs
    /// [`quant::row_bytes`]`(width)` instead of `4 * width`.
    pub fn packed_bytes_per_token(&self) -> usize {
        let k: usize = self.k_width.iter().map(|&w| quant::row_bytes(w)).sum();
        let v: usize = self.v_width.iter().map(|&w| quant::row_bytes(w)).sum();
        self.n_kv_heads * (k + v)
    }

    /// Per-block footprint under `mode` — the divisor that turns an
    /// operator's byte budget into a block budget.
    pub fn bytes_per_block_for(&self, mode: KvStorageMode) -> usize {
        match mode {
            KvStorageMode::F32 => self.bytes_per_block(),
            KvStorageMode::PackedInt4 => self.packed_bytes_per_token() * BLOCK_TOKENS,
        }
    }
}

/// One layer's latent K/V backing store, sized for the whole block budget.
///
/// Layout (both K and V): `[block][kv_head][token_in_block][width]` — a
/// (block, head) pair owns one contiguous run of `BLOCK_TOKENS * width`
/// floats, which is the unit the blocked attention kernels consume.
///
/// Base pointers are captured once at construction (the buffers are never
/// resized) so the batched decode path can hand disjoint-session writers
/// raw row slices without re-borrowing the whole store — same idiom as the
/// matmul kernel's `OutPtr`.
#[derive(Debug)]
pub struct LayerStore {
    k: Vec<f32>,
    v: Vec<f32>,
    k_ptr: *mut f32,
    v_ptr: *mut f32,
    k_width: usize,
    v_width: usize,
    /// Packed-int4 buffers (`KvStorageMode::PackedInt4`): rows live as
    /// `quant` nibble-packed bytes, `k_row_bytes`/`v_row_bytes`-strided,
    /// same `[block][kv_head][token_in_block][row]` order; the f32 buffers
    /// stay empty.  Exactly one of the two buffer families is populated.
    kq: Vec<u8>,
    vq: Vec<u8>,
    kq_ptr: *mut u8,
    vq_ptr: *mut u8,
    k_row_bytes: usize,
    v_row_bytes: usize,
    packed: bool,
}

// SAFETY: the raw pointers alias only `self.k` / `self.v`, and every write
// path goes through `PagedSeqLayer`, whose users write disjoint rows.
// Two conditions make that hold, and BOTH are load-bearing:
//   1. spatial — at decode time every written block has refcount == 1
//      (exclusively owned); blocks shared through the prefix trie
//      (refcount > 1) are only read;
//   2. temporal — a block registered in the trie IS written by its
//      registrant's own prefill, possibly after sharers attached it
//      (registration happens at admission, before the rows exist).  No
//      sharer reads those rows earlier because the coordinator's prefill
//      queue is strictly FIFO: a sharer's first chunk (and any decode)
//      runs only after the registrant's prefill completed.  Reordering or
//      parallelising prefill across sessions would break this even with
//      the refcount rule intact.
unsafe impl Send for LayerStore {}
unsafe impl Sync for LayerStore {}

impl LayerStore {
    fn new(capacity_blocks: usize, n_kv_heads: usize, k_width: usize, v_width: usize) -> LayerStore {
        let mut k = vec![0.0f32; capacity_blocks * n_kv_heads * BLOCK_TOKENS * k_width];
        let mut v = vec![0.0f32; capacity_blocks * n_kv_heads * BLOCK_TOKENS * v_width];
        let (k_ptr, v_ptr) = (k.as_mut_ptr(), v.as_mut_ptr());
        LayerStore {
            k,
            v,
            k_ptr,
            v_ptr,
            k_width,
            v_width,
            kq: Vec::new(),
            vq: Vec::new(),
            kq_ptr: std::ptr::null_mut(),
            vq_ptr: std::ptr::null_mut(),
            k_row_bytes: quant::row_bytes(k_width),
            v_row_bytes: quant::row_bytes(v_width),
            packed: false,
        }
    }

    fn new_packed(
        capacity_blocks: usize,
        n_kv_heads: usize,
        k_width: usize,
        v_width: usize,
    ) -> LayerStore {
        let (k_row_bytes, v_row_bytes) = (quant::row_bytes(k_width), quant::row_bytes(v_width));
        let mut kq = vec![0u8; capacity_blocks * n_kv_heads * BLOCK_TOKENS * k_row_bytes];
        let mut vq = vec![0u8; capacity_blocks * n_kv_heads * BLOCK_TOKENS * v_row_bytes];
        let (kq_ptr, vq_ptr) = (kq.as_mut_ptr(), vq.as_mut_ptr());
        LayerStore {
            k: Vec::new(),
            v: Vec::new(),
            k_ptr: std::ptr::null_mut(),
            v_ptr: std::ptr::null_mut(),
            k_width,
            v_width,
            kq,
            vq,
            kq_ptr,
            vq_ptr,
            k_row_bytes,
            v_row_bytes,
            packed: true,
        }
    }

    fn zero_block(&mut self, block: usize, n_kv_heads: usize) {
        if self.packed {
            // An all-zero packed row decodes to a zero row (scale 0.0), so
            // the zeroed-on-allocation contract carries over unchanged.
            let kn = n_kv_heads * BLOCK_TOKENS * self.k_row_bytes;
            let vn = n_kv_heads * BLOCK_TOKENS * self.v_row_bytes;
            self.kq[block * kn..(block + 1) * kn].fill(0);
            self.vq[block * vn..(block + 1) * vn].fill(0);
            return;
        }
        let kn = n_kv_heads * BLOCK_TOKENS * self.k_width;
        let vn = n_kv_heads * BLOCK_TOKENS * self.v_width;
        self.k[block * kn..(block + 1) * kn].fill(0.0);
        self.v[block * vn..(block + 1) * vn].fill(0.0);
    }

    /// Copy the first `tokens` rows of every KV head from block `src` to
    /// block `dst` — copy-on-write materialisation of a partially shared
    /// prefix block.
    fn copy_rows(&mut self, src: usize, dst: usize, n_kv_heads: usize, tokens: usize) {
        for hd in 0..n_kv_heads {
            if self.packed {
                let ks = ((src * n_kv_heads + hd) * BLOCK_TOKENS) * self.k_row_bytes;
                let kd = ((dst * n_kv_heads + hd) * BLOCK_TOKENS) * self.k_row_bytes;
                self.kq.copy_within(ks..ks + tokens * self.k_row_bytes, kd);
                let vs = ((src * n_kv_heads + hd) * BLOCK_TOKENS) * self.v_row_bytes;
                let vd = ((dst * n_kv_heads + hd) * BLOCK_TOKENS) * self.v_row_bytes;
                self.vq.copy_within(vs..vs + tokens * self.v_row_bytes, vd);
                continue;
            }
            let ks = ((src * n_kv_heads + hd) * BLOCK_TOKENS) * self.k_width;
            let kd = ((dst * n_kv_heads + hd) * BLOCK_TOKENS) * self.k_width;
            self.k.copy_within(ks..ks + tokens * self.k_width, kd);
            let vs = ((src * n_kv_heads + hd) * BLOCK_TOKENS) * self.v_width;
            let vd = ((dst * n_kv_heads + hd) * BLOCK_TOKENS) * self.v_width;
            self.v.copy_within(vs..vs + tokens * self.v_width, vd);
        }
    }

    /// Copy one token row (every KV head) from `(src_block, src_slot)` to
    /// `(dst_block, dst_slot)` — the retention compaction move.  Handles
    /// both storage families; src and dst may be the same block (slots
    /// never overlap: compaction only moves rows to strictly lower slots).
    fn copy_row(
        &mut self,
        src_block: usize,
        src_slot: usize,
        dst_block: usize,
        dst_slot: usize,
        n_kv_heads: usize,
    ) {
        for hd in 0..n_kv_heads {
            if self.packed {
                let ks = ((src_block * n_kv_heads + hd) * BLOCK_TOKENS + src_slot) * self.k_row_bytes;
                let kd = ((dst_block * n_kv_heads + hd) * BLOCK_TOKENS + dst_slot) * self.k_row_bytes;
                self.kq.copy_within(ks..ks + self.k_row_bytes, kd);
                let vs = ((src_block * n_kv_heads + hd) * BLOCK_TOKENS + src_slot) * self.v_row_bytes;
                let vd = ((dst_block * n_kv_heads + hd) * BLOCK_TOKENS + dst_slot) * self.v_row_bytes;
                self.vq.copy_within(vs..vs + self.v_row_bytes, vd);
                continue;
            }
            let ks = ((src_block * n_kv_heads + hd) * BLOCK_TOKENS + src_slot) * self.k_width;
            let kd = ((dst_block * n_kv_heads + hd) * BLOCK_TOKENS + dst_slot) * self.k_width;
            self.k.copy_within(ks..ks + self.k_width, kd);
            let vs = ((src_block * n_kv_heads + hd) * BLOCK_TOKENS + src_slot) * self.v_width;
            let vd = ((dst_block * n_kv_heads + hd) * BLOCK_TOKENS + dst_slot) * self.v_width;
            self.v.copy_within(vs..vs + self.v_width, vd);
        }
    }
}

/// Read/write access to one sequence's latent K/V rows for one layer.
///
/// Implemented by the dense per-sequence `model::LayerCache` and by the
/// paged [`PagedSeqLayer`]; the engine's projection/attention kernels are
/// generic over this trait, so both layouts execute identical arithmetic.
pub trait KvLayerView {
    fn k_row(&self, head: usize, t: usize) -> &[f32];
    fn v_row(&self, head: usize, t: usize) -> &[f32];
    fn k_row_mut(&mut self, head: usize, t: usize) -> &mut [f32];
    fn v_row_mut(&mut self, head: usize, t: usize) -> &mut [f32];
    /// Visit the contiguous runs of K rows covering tokens `[0, s)` of
    /// `head`, in ascending token order.  The callback receives the first
    /// token index of the run and a slice of `run_len * k_width` floats.
    fn for_k_runs<F: FnMut(usize, &[f32])>(&self, head: usize, s: usize, f: F);
    /// Same for V rows.
    fn for_v_runs<F: FnMut(usize, &[f32])>(&self, head: usize, s: usize, f: F);
    /// Visit the contiguous runs of K rows covering tokens `[t0, t0 + n)`
    /// of `head` *mutably*, in ascending token order — the chunked-prefill
    /// write path: one callback per run instead of one row lookup per
    /// token.  The callback receives the first token index of the run and
    /// a mutable slice of `run_len * k_width` floats.
    fn for_k_runs_mut<F: FnMut(usize, &mut [f32])>(&mut self, head: usize, t0: usize, n: usize, f: F);
    /// Same for V rows.
    fn for_v_runs_mut<F: FnMut(usize, &mut [f32])>(&mut self, head: usize, t0: usize, n: usize, f: F);

    /// Does this view store rows nibble-packed (`KvStorageMode::PackedInt4`)?
    /// When true, the f32 row accessors are unavailable; readers use the
    /// `_q4` run visitors and writers go through `write_k_row`/`write_v_row`.
    fn packed_q4(&self) -> bool {
        false
    }

    /// Store a freshly projected K row at `(head, t)`, quantizing in place
    /// when the store is packed.  The default (f32 stores) is a plain copy.
    fn write_k_row(&mut self, head: usize, t: usize, row: &[f32]) {
        self.k_row_mut(head, t).copy_from_slice(row);
    }

    /// Same for V rows.
    fn write_v_row(&mut self, head: usize, t: usize, row: &[f32]) {
        self.v_row_mut(head, t).copy_from_slice(row);
    }

    /// Logical (RoPE) position of physical row `t`.  Dense caches and
    /// retain-all paged sessions are the identity map; a pressed session
    /// reports each surviving row's original position so attention scores
    /// stay correct after compaction.
    fn row_pos(&self, t: usize) -> usize {
        t
    }

    /// Does this view carry an explicit (non-identity) logical→physical
    /// map?  The engine uses this to pick between the seed chunk-RoPE fast
    /// path and per-row position application.
    fn has_positions(&self) -> bool {
        false
    }

    /// Accumulate one query's post-softmax attention mass `scores[0..s]`
    /// into the session's per-row score accounting (feeds the `AttnScore`
    /// press).  Default: no accounting (dense caches, untracked sessions).
    fn score_accum(&self, _s: usize, _scores: &[f32]) {}

    /// Packed-row analogue of [`KvLayerView::for_k_runs`]: visits runs of
    /// `run_len * quant::row_bytes(k_width)` packed bytes.  Only
    /// implemented by packed stores.
    fn for_k_runs_q4<F: FnMut(usize, &[u8])>(&self, _head: usize, _s: usize, _f: F) {
        unreachable!("for_k_runs_q4 on a non-packed KV view");
    }

    /// Same for V rows.
    fn for_v_runs_q4<F: FnMut(usize, &[u8])>(&self, _head: usize, _s: usize, _f: F) {
        unreachable!("for_v_runs_q4 on a non-packed KV view");
    }
}

/// One session × one layer window into the paged store: rows are addressed
/// through the session's page table, runs are per-block contiguous.
///
/// Constructed via [`StorePtrs::seq_layer`].  Writers for different
/// sessions may exist concurrently (batched decode parallelises across
/// sessions); the allocator guarantees their block sets are disjoint.
pub struct PagedSeqLayer<'a> {
    k_base: *mut f32,
    v_base: *mut f32,
    blocks: &'a [usize],
    n_kv_heads: usize,
    k_width: usize,
    v_width: usize,
    /// Packed-int4 addressing (`KvStorageMode::PackedInt4`): base pointers
    /// into the byte buffers and the per-row byte strides.  When `packed`
    /// the f32 accessors panic — readers go through the `_q4` visitors.
    kq_base: *mut u8,
    vq_base: *mut u8,
    k_row_bytes: usize,
    v_row_bytes: usize,
    packed: bool,
    /// Logical position of each physical row, `None` for identity
    /// (retain-all) sessions — see [`KvLayerView::row_pos`].
    positions: Option<&'a [u32]>,
    /// Per-row attention-mass sink (null unless the session tracks scores
    /// for the `AttnScore` press).  Written through `&self` under the same
    /// disjoint-session argument as the row stores.
    scores: *mut f32,
    rows: usize,
}

// SAFETY: see `LayerStore` — disjoint *written* blocks per session
// (shared prefix blocks are read-only).
unsafe impl Send for PagedSeqLayer<'_> {}
// SAFETY: every `&self` method only reads; mutation requires `&mut self`,
// which Rust's borrow rules keep exclusive.  Sharing a view across the
// chunked-prefill attention workers (read-only score/context sweeps) is
// therefore sound — the chunk's K/V rows are fully written before the
// shared borrow is taken.
unsafe impl Sync for PagedSeqLayer<'_> {}

impl PagedSeqLayer<'_> {
    #[inline]
    fn k_off(&self, head: usize, t: usize) -> usize {
        debug_assert!(!self.packed, "f32 K access on a packed store");
        let (block, slot) = (self.blocks[t / BLOCK_TOKENS], t % BLOCK_TOKENS);
        ((block * self.n_kv_heads + head) * BLOCK_TOKENS + slot) * self.k_width
    }

    #[inline]
    fn v_off(&self, head: usize, t: usize) -> usize {
        debug_assert!(!self.packed, "f32 V access on a packed store");
        let (block, slot) = (self.blocks[t / BLOCK_TOKENS], t % BLOCK_TOKENS);
        ((block * self.n_kv_heads + head) * BLOCK_TOKENS + slot) * self.v_width
    }

    #[inline]
    fn kq_off(&self, head: usize, t: usize) -> usize {
        debug_assert!(self.packed, "packed K access on an f32 store");
        let (block, slot) = (self.blocks[t / BLOCK_TOKENS], t % BLOCK_TOKENS);
        ((block * self.n_kv_heads + head) * BLOCK_TOKENS + slot) * self.k_row_bytes
    }

    #[inline]
    fn vq_off(&self, head: usize, t: usize) -> usize {
        debug_assert!(self.packed, "packed V access on an f32 store");
        let (block, slot) = (self.blocks[t / BLOCK_TOKENS], t % BLOCK_TOKENS);
        ((block * self.n_kv_heads + head) * BLOCK_TOKENS + slot) * self.v_row_bytes
    }
}

impl KvLayerView for PagedSeqLayer<'_> {
    #[inline]
    fn k_row(&self, head: usize, t: usize) -> &[f32] {
        unsafe { std::slice::from_raw_parts(self.k_base.add(self.k_off(head, t)), self.k_width) }
    }

    #[inline]
    fn v_row(&self, head: usize, t: usize) -> &[f32] {
        unsafe { std::slice::from_raw_parts(self.v_base.add(self.v_off(head, t)), self.v_width) }
    }

    #[inline]
    fn k_row_mut(&mut self, head: usize, t: usize) -> &mut [f32] {
        unsafe {
            std::slice::from_raw_parts_mut(self.k_base.add(self.k_off(head, t)), self.k_width)
        }
    }

    #[inline]
    fn v_row_mut(&mut self, head: usize, t: usize) -> &mut [f32] {
        unsafe {
            std::slice::from_raw_parts_mut(self.v_base.add(self.v_off(head, t)), self.v_width)
        }
    }

    fn for_k_runs<F: FnMut(usize, &[f32])>(&self, head: usize, s: usize, mut f: F) {
        let mut t0 = 0;
        while t0 < s {
            let run = (s - t0).min(BLOCK_TOKENS);
            let rows = unsafe {
                std::slice::from_raw_parts(
                    self.k_base.add(self.k_off(head, t0)),
                    run * self.k_width,
                )
            };
            f(t0, rows);
            t0 += run;
        }
    }

    fn for_v_runs<F: FnMut(usize, &[f32])>(&self, head: usize, s: usize, mut f: F) {
        let mut t0 = 0;
        while t0 < s {
            let run = (s - t0).min(BLOCK_TOKENS);
            let rows = unsafe {
                std::slice::from_raw_parts(
                    self.v_base.add(self.v_off(head, t0)),
                    run * self.v_width,
                )
            };
            f(t0, rows);
            t0 += run;
        }
    }

    fn for_k_runs_mut<F: FnMut(usize, &mut [f32])>(&mut self, head: usize, t0: usize, n: usize, mut f: F) {
        let (mut t, end) = (t0, t0 + n);
        while t < end {
            // A chunk may start mid-block: the first run ends at the block
            // boundary, later runs are whole blocks (or the chunk tail).
            let run = (end - t).min(BLOCK_TOKENS - t % BLOCK_TOKENS);
            let rows = unsafe {
                std::slice::from_raw_parts_mut(
                    self.k_base.add(self.k_off(head, t)),
                    run * self.k_width,
                )
            };
            f(t, rows);
            t += run;
        }
    }

    fn for_v_runs_mut<F: FnMut(usize, &mut [f32])>(&mut self, head: usize, t0: usize, n: usize, mut f: F) {
        let (mut t, end) = (t0, t0 + n);
        while t < end {
            let run = (end - t).min(BLOCK_TOKENS - t % BLOCK_TOKENS);
            let rows = unsafe {
                std::slice::from_raw_parts_mut(
                    self.v_base.add(self.v_off(head, t)),
                    run * self.v_width,
                )
            };
            f(t, rows);
            t += run;
        }
    }

    fn packed_q4(&self) -> bool {
        self.packed
    }

    #[inline]
    fn row_pos(&self, t: usize) -> usize {
        match self.positions {
            Some(pv) => pv[t] as usize,
            None => t,
        }
    }

    fn has_positions(&self) -> bool {
        self.positions.is_some()
    }

    fn score_accum(&self, s: usize, scores: &[f32]) {
        if self.scores.is_null() {
            return;
        }
        debug_assert!(s <= self.rows && s <= scores.len());
        // SAFETY: `scores` points at the session's `row_scores` buffer,
        // sized to its row count; decode parallelism is across sessions,
        // so no two writers target the same buffer.
        unsafe {
            for (t, &w) in scores.iter().enumerate().take(s) {
                *self.scores.add(t) += w;
            }
        }
    }

    fn write_k_row(&mut self, head: usize, t: usize, row: &[f32]) {
        if self.packed {
            debug_assert_eq!(row.len(), self.k_width);
            let dst = unsafe {
                std::slice::from_raw_parts_mut(
                    self.kq_base.add(self.kq_off(head, t)),
                    self.k_row_bytes,
                )
            };
            quant::quantize_row_into(row, dst);
        } else {
            self.k_row_mut(head, t).copy_from_slice(row);
        }
    }

    fn write_v_row(&mut self, head: usize, t: usize, row: &[f32]) {
        if self.packed {
            debug_assert_eq!(row.len(), self.v_width);
            let dst = unsafe {
                std::slice::from_raw_parts_mut(
                    self.vq_base.add(self.vq_off(head, t)),
                    self.v_row_bytes,
                )
            };
            quant::quantize_row_into(row, dst);
        } else {
            self.v_row_mut(head, t).copy_from_slice(row);
        }
    }

    fn for_k_runs_q4<F: FnMut(usize, &[u8])>(&self, head: usize, s: usize, mut f: F) {
        let mut t0 = 0;
        while t0 < s {
            let run = (s - t0).min(BLOCK_TOKENS);
            let rows = unsafe {
                std::slice::from_raw_parts(
                    self.kq_base.add(self.kq_off(head, t0)),
                    run * self.k_row_bytes,
                )
            };
            f(t0, rows);
            t0 += run;
        }
    }

    fn for_v_runs_q4<F: FnMut(usize, &[u8])>(&self, head: usize, s: usize, mut f: F) {
        let mut t0 = 0;
        while t0 < s {
            let run = (s - t0).min(BLOCK_TOKENS);
            let rows = unsafe {
                std::slice::from_raw_parts(
                    self.vq_base.add(self.vq_off(head, t0)),
                    run * self.v_row_bytes,
                )
            };
            f(t0, rows);
            t0 += run;
        }
    }
}

/// Shared read view of the per-session page tables (block id lists).
#[derive(Clone, Copy)]
pub struct PageTables<'a> {
    tables: &'a BTreeMap<u64, SessionAlloc>,
}

impl<'a> PageTables<'a> {
    pub fn blocks(&self, session: u64) -> Option<&'a [usize]> {
        self.tables.get(&session).map(|t| t.blocks.as_slice())
    }

    pub fn tokens(&self, session: u64) -> usize {
        self.tables.get(&session).map(|t| t.tokens).unwrap_or(0)
    }

    /// Full per-session view: page table plus the logical→physical token
    /// map and score sink the engine threads into [`PagedSeqLayer`].
    pub fn view(&self, session: u64) -> Option<SessionKvView<'a>> {
        self.tables.get(&session).map(|t| SessionKvView {
            blocks: t.blocks.as_slice(),
            positions: t.positions.as_deref(),
            scores: if t.track_scores { t.scores_ptr } else { std::ptr::null_mut() },
            rows: t.tokens,
        })
    }
}

/// One session's engine-facing KV identity: its page table, its
/// logical→physical token map (`None` = identity / retain-all), and its
/// per-row attention-score sink (null unless tracked).
#[derive(Clone, Copy)]
pub struct SessionKvView<'a> {
    pub blocks: &'a [usize],
    pub positions: Option<&'a [u32]>,
    scores: *mut f32,
    pub rows: usize,
}

// SAFETY: the score pointer targets the session's own `row_scores` buffer;
// decode workers operate on disjoint sessions (same argument as
// `PagedSeqLayer`), and the buffer is never resized while `StorePtrs`
// borrows the cache exclusively.
unsafe impl Send for SessionKvView<'_> {}
unsafe impl Sync for SessionKvView<'_> {}

/// Raw per-layer handles into the backing store, witnessed by an exclusive
/// borrow of the owning `PagedKvCache` (so no other reader/writer of the
/// storage exists while these are live).
pub struct StorePtrs<'a> {
    layers: &'a [LayerStore],
    n_kv_heads: usize,
    _excl: PhantomData<&'a mut ()>,
}

// SAFETY: handed to scoped workers that write disjoint sessions' blocks.
unsafe impl Send for StorePtrs<'_> {}
unsafe impl Sync for StorePtrs<'_> {}

impl<'a> StorePtrs<'a> {
    /// View of `session`'s rows in layer `l` (its page table is `blocks`).
    ///
    /// # Safety
    ///
    /// The caller must not let two views over the *same* page table be
    /// written (or written + read) at the same time — that would alias
    /// mutable memory.  Views over *different* sessions may be used in
    /// parallel during decode: each session writes rows only at positions
    /// at or beyond its own prefill start (`matched_tokens`), which live
    /// in blocks it owns exclusively (refcount == 1), while prefix blocks
    /// shared across views (refcount > 1) are only read.  The registrant
    /// of a shared block *does* write it during its own prefill — that is
    /// safe only because FIFO prefill ordering runs it before any
    /// sharer's first read (see the `LayerStore` SAFETY note).
    pub unsafe fn seq_layer(&self, l: usize, blocks: &'a [usize]) -> PagedSeqLayer<'a> {
        let ls = &self.layers[l];
        PagedSeqLayer {
            k_base: ls.k_ptr,
            v_base: ls.v_ptr,
            blocks,
            n_kv_heads: self.n_kv_heads,
            k_width: ls.k_width,
            v_width: ls.v_width,
            kq_base: ls.kq_ptr,
            vq_base: ls.vq_ptr,
            k_row_bytes: ls.k_row_bytes,
            v_row_bytes: ls.v_row_bytes,
            packed: ls.packed,
            positions: None,
            scores: std::ptr::null_mut(),
            rows: blocks.len() * BLOCK_TOKENS,
        }
    }

    /// Session-aware variant of [`StorePtrs::seq_layer`]: threads the
    /// session's logical→physical map and score sink into the view.  With
    /// identity positions and no tracking this is exactly `seq_layer`.
    ///
    /// # Safety
    ///
    /// Same contract as [`StorePtrs::seq_layer`].
    pub unsafe fn session_layer(&self, l: usize, sv: &SessionKvView<'a>) -> PagedSeqLayer<'a> {
        let mut view = unsafe { self.seq_layer(l, sv.blocks) };
        view.positions = sv.positions;
        view.scores = sv.scores;
        view.rows = sv.rows;
        view
    }
}

/// Paged block allocator with per-session page tables (and, in
/// `with_storage` mode, the latent K/V backing store itself).
///
/// Capacity is expressed in bytes (as an operator would configure it); the
/// block budget adapts to the variant's width, so a RAP-compressed model
/// fits proportionally more tokens in the same budget — the deployability
/// claim of the paper's introduction.
#[derive(Debug)]
pub struct PagedKvCache {
    pub shape: CacheShape,
    capacity_blocks: usize,
    free: Vec<usize>,
    /// Per-block reader count: 0 = free, 1 = exclusively owned, >1 =
    /// shared through the prefix trie (read-only).
    refcount: Vec<u32>,
    /// session -> block ids (one entry per BLOCK_TOKENS tokens).
    tables: BTreeMap<u64, SessionAlloc>,
    /// Block-aligned prompt-prefix index over resident blocks.
    trie: prefix::PrefixTrie,
    peak_used: usize,
    store: Option<Vec<LayerStore>>,
    /// Row layout of the backing store (`F32` for accounting-only caches).
    storage_mode: KvStorageMode,
    /// Keep released prefix nodes resident as evictable cold entries
    /// (see the module docs).  Off by default: unit tests and standalone
    /// users keep the strict "last release frees everything" model.
    retain_cold: bool,
    /// Blocks held only by the cold-prefix cache (one per cold node).
    cold_blocks: usize,
    /// Deterministic logical clock for cold-entry LRU: bumped once per
    /// reserve/release, never wall time.
    clock: u64,
    /// Cold entries evicted under pressure (diagnostics).
    evictions: u64,
    /// Retention presses that evicted at least one row.
    presses: u64,
    /// Token rows evicted by retention presses (cumulative).
    evicted_rows: u64,
    /// Seeded fault stream for allocation sites (None in production).
    alloc_faults: Option<FaultInjector>,
}

#[derive(Debug, Clone)]
struct SessionAlloc {
    blocks: Vec<usize>,
    tokens: usize,
    /// Leading blocks attached from the prefix trie — read-only to this
    /// session (their refcount counts other readers too).
    shared_blocks: usize,
    /// Trie nodes this session holds a reference on, in prefix order
    /// (matched-and-attached nodes, then nodes it registered itself).
    trie_path: Vec<usize>,
    /// Pending copy-on-write of a partially matched prefix block.
    cow: Option<CowPending>,
    /// Tokens whose rows have actually been written (shared prefix at
    /// admission + prefill progress reported via
    /// [`PagedKvCache::note_filled`]).  Feeds the debug-time readiness
    /// tripwire for the FIFO-ordering safety argument; not used for
    /// accounting.  After a retention press this counts *rows*, remapped
    /// through the keep set.
    filled: usize,
    /// Logical position of each physical row, ascending.  `None` means the
    /// identity map (retain-all) — the seed fast paths key off this being
    /// `None`, which is what keeps the default bit-identical.  Set once by
    /// the first press (or a pruned resume) and maintained thereafter.
    positions: Option<Vec<u32>>,
    /// Logical sequence length: the next position `ensure_tokens` would
    /// materialise.  Equals `tokens` for identity sessions; after a press
    /// it exceeds `tokens` by the number of evicted rows.
    next_pos: usize,
    /// Accumulate post-softmax attention mass per row (the `AttnScore`
    /// press input).  Off by default; enabled per session at admission.
    track_scores: bool,
    /// Cumulative attention mass per physical row (compacted alongside the
    /// rows).  Empty unless `track_scores`.
    row_scores: Vec<f32>,
    /// Cached `row_scores.as_mut_ptr()`, refreshed by
    /// [`PagedKvCache::tables_and_ptrs`] so decode workers can accumulate
    /// through the shared `PageTables` borrow — same idiom as
    /// `LayerStore`'s base pointers.
    scores_ptr: *mut f32,
}

// SAFETY: `scores_ptr` aliases only this session's own `row_scores`
// buffer; it is refreshed under `&mut self` before every decode and only
// dereferenced by that decode's disjoint-session workers (see
// `LayerStore`'s SAFETY note for the full argument).
unsafe impl Send for SessionAlloc {}
unsafe impl Sync for SessionAlloc {}

impl SessionAlloc {
    fn empty() -> SessionAlloc {
        SessionAlloc {
            blocks: Vec::new(),
            tokens: 0,
            shared_blocks: 0,
            trie_path: Vec::new(),
            cow: None,
            filled: 0,
            positions: None,
            next_pos: 0,
            track_scores: false,
            row_scores: Vec::new(),
            scores_ptr: std::ptr::null_mut(),
        }
    }
}

#[derive(Debug, Clone, Copy)]
struct CowPending {
    /// Shared source block; one refcount is held on it until release so it
    /// cannot be recycled before (or after) the copy.
    src_block: usize,
    /// Session whose prefill writes the source rows (debug tripwire).
    src_session: u64,
    /// Rows `[0, tokens)` of the block are copied.
    tokens: usize,
    /// Index in `SessionAlloc::blocks` of the private destination block.
    dst_index: usize,
    done: bool,
}

/// Outcome of a prefix-aware reservation ([`PagedKvCache::reserve_prefix`]).
#[derive(Debug, Clone, Copy, Default)]
pub struct PrefixReservation {
    /// Prompt tokens covered by already-resident shared blocks — chunked
    /// prefill can start at this position.
    pub matched_tokens: usize,
    /// Fully shared leading blocks (attached instead of allocated).
    pub shared_blocks: usize,
}

impl PagedKvCache {
    /// Accounting-only allocator (backends that own KV state elsewhere).
    pub fn new(shape: CacheShape, capacity_bytes: usize) -> PagedKvCache {
        let capacity_blocks = capacity_bytes / shape.bytes_per_block().max(1);
        PagedKvCache {
            free: (0..capacity_blocks).rev().collect(),
            refcount: vec![0; capacity_blocks],
            tables: BTreeMap::new(),
            trie: prefix::PrefixTrie::new(),
            peak_used: 0,
            store: None,
            storage_mode: KvStorageMode::F32,
            retain_cold: false,
            cold_blocks: 0,
            clock: 0,
            evictions: 0,
            presses: 0,
            evicted_rows: 0,
            alloc_faults: None,
            capacity_blocks,
            shape,
        }
    }

    /// Allocator that also owns the latent K/V storage the pure-Rust engine
    /// decodes from (full-precision f32 rows).
    pub fn with_storage(shape: CacheShape, capacity_bytes: usize) -> PagedKvCache {
        PagedKvCache::with_storage_mode(shape, capacity_bytes, KvStorageMode::F32)
    }

    /// Storage-backed allocator with an explicit row layout.  Under
    /// `PackedInt4` the same byte budget yields proportionally more blocks
    /// (the per-block footprint shrinks to
    /// [`CacheShape::bytes_per_block_for`]), which is the fused-int4
    /// capacity win the metrics report as resident KV bytes.
    pub fn with_storage_mode(
        shape: CacheShape,
        capacity_bytes: usize,
        mode: KvStorageMode,
    ) -> PagedKvCache {
        let mut kv = PagedKvCache::new(shape, capacity_bytes);
        if mode.is_packed() {
            let blocks = capacity_bytes / kv.shape.bytes_per_block_for(mode).max(1);
            kv.capacity_blocks = blocks;
            kv.free = (0..blocks).rev().collect();
            kv.refcount = vec![0; blocks];
        }
        kv.storage_mode = mode;
        let store = (0..kv.shape.n_layers)
            .map(|l| {
                let (blocks, heads) = (kv.capacity_blocks, kv.shape.n_kv_heads);
                let (kw, vw) = (kv.shape.k_width[l], kv.shape.v_width[l]);
                match mode {
                    KvStorageMode::F32 => LayerStore::new(blocks, heads, kw, vw),
                    KvStorageMode::PackedInt4 => LayerStore::new_packed(blocks, heads, kw, vw),
                }
            })
            .collect();
        kv.store = Some(store);
        kv
    }

    pub fn has_storage(&self) -> bool {
        self.store.is_some()
    }

    pub fn capacity_blocks(&self) -> usize {
        self.capacity_blocks
    }

    /// Blocks held by live sessions.  Blocks parked in the cold-prefix
    /// cache are *reclaimable* (evicted on demand) and excluded, so this
    /// returns to its pre-admission baseline once every session releases
    /// even while the cold cache is warm.
    pub fn used_blocks(&self) -> usize {
        self.capacity_blocks - self.free.len() - self.cold_blocks
    }

    /// Blocks resident only as cold prefix-cache entries.
    pub fn cold_blocks(&self) -> usize {
        self.cold_blocks
    }

    /// Cold prefix entries evicted under pressure so far.
    pub fn evictions(&self) -> u64 {
        self.evictions
    }

    pub fn peak_used_blocks(&self) -> usize {
        self.peak_used
    }

    /// Row layout of the backing store (`F32` for accounting-only caches).
    pub fn storage_mode(&self) -> KvStorageMode {
        self.storage_mode
    }

    pub fn used_bytes(&self) -> usize {
        self.used_blocks() * self.shape.bytes_per_block_for(self.storage_mode)
    }

    /// Bytes physically resident for KV rows under the active storage mode
    /// — hot session blocks plus cold prefix-cache blocks.  Under
    /// `PackedInt4` this is what makes the fused-int4 capacity win visible
    /// next to `used_blocks`/`cold_blocks` in the serving report.
    pub fn resident_kv_bytes(&self) -> usize {
        (self.used_blocks() + self.cold_blocks) * self.shape.bytes_per_block_for(self.storage_mode)
    }

    /// Max tokens a fresh session could hold right now (cold blocks count:
    /// they are evicted on demand when a reservation needs them).
    pub fn free_token_capacity(&self) -> usize {
        (self.free.len() + self.cold_blocks) * BLOCK_TOKENS
    }

    /// Keep released prefix nodes resident as evictable cold entries.
    /// Only meaningful for storage-backed caches (accounting-only caches
    /// never populate the trie); safe to set either way.
    pub fn retain_cold_prefixes(&mut self, on: bool) {
        self.retain_cold = on;
    }

    /// Thread a seeded allocation-fault stream in ([`FaultInjector`]):
    /// reservations that need new blocks then fail at seeded points with
    /// a typed [`crate::faults::InjectedFault`].  `None` disables.
    pub fn set_alloc_faults(&mut self, inj: Option<FaultInjector>) {
        self.alloc_faults = inj;
    }

    /// Allocation faults injected so far (0 without a fault stream).
    pub fn alloc_faults_injected(&self) -> u64 {
        self.alloc_faults.as_ref().map(|f| f.injected()).unwrap_or(0)
    }

    pub fn session_tokens(&self, session: u64) -> usize {
        self.tables.get(&session).map(|t| t.tokens).unwrap_or(0)
    }

    pub fn sessions(&self) -> usize {
        self.tables.len()
    }

    /// Gate for any reservation that needs `deficit` fresh blocks: consult
    /// the seeded fault stream (deficit > 0 only — zero-deficit fast paths
    /// never draw), then evict cold prefix entries until the free list
    /// covers the deficit, and only then report genuine exhaustion.
    fn alloc_gate(&mut self, deficit: usize) -> Result<()> {
        if deficit == 0 {
            return Ok(());
        }
        if let Some(inj) = &mut self.alloc_faults {
            if inj.fires() {
                return Err(anyhow::Error::new(inj.fault()));
            }
        }
        while self.free.len() < deficit {
            let Some(node) = self.trie.best_eviction(self.clock) else { break };
            let block = self.trie.evict(node);
            self.cold_blocks -= 1;
            self.evictions += 1;
            // Usually frees the block; a CoW reader may still hold it, in
            // which case the loop tries the next-best cold leaf.
            self.dec_block(block);
        }
        if deficit > self.free.len() {
            bail!(
                "kv-cache exhausted: need {deficit} blocks, {} free (capacity {})",
                self.free.len(),
                self.capacity_blocks
            );
        }
        Ok(())
    }

    /// Pop one free block, mark it exclusively owned, and zero its rows.
    /// Callers go through [`PagedKvCache::alloc_gate`] first.
    fn take_free_block(&mut self) -> usize {
        let block = self.free.pop().unwrap();
        self.refcount[block] = 1;
        // Zero recycled blocks so a new session can never observe a
        // previous session's rows (and unwritten positions read as 0).
        if let Some(store) = &mut self.store {
            for ls in store.iter_mut() {
                ls.zero_block(block, self.shape.n_kv_heads);
            }
        }
        block
    }

    /// Reserve capacity for `tokens` more tokens of `session`, allocating
    /// (and, with storage, zeroing) blocks as needed.  Fails (backpressure
    /// signal) when out of blocks, after evicting cold prefix entries.
    /// A failed reservation never creates (or leaves) a session entry, so
    /// admission retries through `reserve_prefix` cannot wedge.
    pub fn reserve(&mut self, session: u64, tokens: usize) -> Result<()> {
        let (have_tokens, have_blocks) = self
            .tables
            .get(&session)
            .map(|e| (e.tokens, e.blocks.len()))
            .unwrap_or((0, 0));
        let needed_tokens = have_tokens + tokens;
        let needed_blocks = needed_tokens.div_ceil(BLOCK_TOKENS);
        let deficit = needed_blocks.saturating_sub(have_blocks);
        self.alloc_gate(deficit)?;
        self.clock += 1;
        for _ in 0..deficit {
            let block = self.take_free_block();
            self.tables
                .entry(session)
                .or_insert_with(SessionAlloc::empty)
                .blocks
                .push(block);
        }
        let e = self.tables.entry(session).or_insert_with(SessionAlloc::empty);
        debug_assert!(
            e.positions.is_none(),
            "reserve() on a pruned session {session} (grow through ensure_tokens)"
        );
        e.tokens = needed_tokens;
        e.next_pos = needed_tokens;
        if e.track_scores {
            e.row_scores.resize(needed_tokens, 0.0);
        }
        self.peak_used = self.peak_used.max(self.used_blocks());
        Ok(())
    }

    /// First reservation for `session`, sharing any block-aligned prompt
    /// prefix already resident: the longest cached prefix (capped so at
    /// least one prompt token remains for this session to prefill — the
    /// final token's logits must come from *its* forward pass) is attached
    /// read-only with refcounts instead of being allocated, a partially
    /// matched trailing block becomes a pending copy-on-write
    /// ([`PagedKvCache::materialize_cow`]), and only the unmatched
    /// remainder of `total_tokens` draws fresh blocks.  The session's own
    /// full prompt chunks are registered in the trie so later admissions
    /// can share them (their rows are computed by this session's prefill,
    /// which FIFO chunked admission runs before any sharer's first chunk).
    ///
    /// Accounting-only caches (no storage to share) fall back to a plain
    /// [`PagedKvCache::reserve`] and report no match.
    pub fn reserve_prefix(
        &mut self,
        session: u64,
        prompt: &[u8],
        total_tokens: usize,
    ) -> Result<PrefixReservation> {
        if self.tables.contains_key(&session) {
            bail!("session {session} already holds a reservation");
        }
        if total_tokens < prompt.len() {
            bail!(
                "reservation of {total_tokens} tokens smaller than the {}-token prompt",
                prompt.len()
            );
        }
        if self.store.is_none() {
            self.reserve(session, total_tokens)?;
            return Ok(PrefixReservation::default());
        }
        let path = self.trie.lookup(prompt);
        let mut matched = (path.len() * BLOCK_TOKENS).min(prompt.len());
        if matched == prompt.len() && matched > 0 {
            matched -= 1;
        }
        let full_shared = matched / BLOCK_TOKENS;
        let partial = matched % BLOCK_TOKENS;
        let total_blocks = total_tokens.div_ceil(BLOCK_TOKENS);
        let fresh = total_blocks - full_shared;
        // Attach the matched path (and take the CoW source hold) BEFORE
        // the allocation gate: attaching revives cold nodes and makes
        // them hot, so the gate's evictor can never reclaim a block this
        // session is about to read.
        let mut blocks = Vec::with_capacity(total_blocks);
        let mut trie_path = Vec::with_capacity(full_shared);
        for &(node, block) in &path[..full_shared] {
            let revived = self.trie.attach(node);
            trie_path.push(node);
            if revived {
                // The cache's cold hold on the block transfers to this
                // session — the refcount already counts it.
                self.cold_blocks -= 1;
            } else {
                self.refcount[block] += 1;
            }
            blocks.push(block);
        }
        let cow = if partial > 0 {
            // The match ends mid-block (only when the trie covered the
            // whole prompt): hold the source block and copy its leading
            // rows into a private block before this session's first write.
            // The raw refcount (without attaching the node) keeps the
            // block resident even if the node itself is cold and gets
            // evicted before the copy runs.
            let (src_node, src_block) = path[full_shared];
            self.refcount[src_block] += 1;
            Some(CowPending {
                src_block,
                src_session: self.trie.node_owner(src_node),
                tokens: partial,
                dst_index: full_shared,
                done: false,
            })
        } else {
            None
        };
        if let Err(e) = self.alloc_gate(fresh) {
            // Roll the attaches back exactly: revived nodes return to
            // cold (the hold goes back to the cache), plain attaches drop
            // the refcount they added.
            for &(node, block) in path[..full_shared].iter().rev() {
                if self.retain_cold {
                    if self.trie.release_to_cold(node, self.clock) {
                        self.cold_blocks += 1;
                    } else {
                        self.dec_block(block);
                    }
                } else {
                    self.trie.release(node);
                    self.dec_block(block);
                }
            }
            if let Some(c) = &cow {
                self.dec_block(c.src_block);
            }
            return Err(e);
        }
        self.clock += 1;
        for _ in full_shared..total_blocks {
            let block = self.take_free_block();
            blocks.push(block);
        }
        if cow.is_none() {
            // Register this prompt's own full chunks beyond the matched
            // path (none exist beyond it, or lookup would have gone
            // deeper).  With a partial match the trie already holds every
            // full chunk of the prompt.
            let mut parent = path.last().map(|&(n, _)| n).unwrap_or(prefix::ROOT);
            for j in path.len()..prompt.len() / BLOCK_TOKENS {
                let chunk = &prompt[j * BLOCK_TOKENS..(j + 1) * BLOCK_TOKENS];
                let node = self.trie.insert_child(parent, chunk, blocks[j], session);
                trie_path.push(node);
                parent = node;
            }
        }
        self.tables.insert(
            session,
            SessionAlloc {
                blocks,
                tokens: total_tokens,
                shared_blocks: full_shared,
                trie_path,
                cow,
                filled: matched,
                next_pos: total_tokens,
                ..SessionAlloc::empty()
            },
        );
        self.peak_used = self.peak_used.max(self.used_blocks());
        Ok(PrefixReservation { matched_tokens: matched, shared_blocks: full_shared })
    }

    /// Perform `session`'s pending copy-on-write, if any: the partially
    /// matched prefix block's leading rows are copied from the shared
    /// source into the session's private block, which its first prefill
    /// chunk then writes into.  Idempotent; a no-op without a pending copy
    /// or on an accounting-only cache.  Must run after the source
    /// session's prefill has produced those rows — the coordinator's FIFO
    /// chunked prefill guarantees it by calling this right before each of
    /// the session's own prefill chunks.
    pub fn materialize_cow(&mut self, session: u64) {
        #[cfg(debug_assertions)]
        self.debug_assert_prefix_ready(session);
        let Some(alloc) = self.tables.get_mut(&session) else { return };
        let Some(cow) = &mut alloc.cow else { return };
        if cow.done {
            return;
        }
        cow.done = true;
        let (src, tokens, dst) = (cow.src_block, cow.tokens, alloc.blocks[cow.dst_index]);
        let n_kv_heads = self.shape.n_kv_heads;
        if let Some(store) = &mut self.store {
            for ls in store.iter_mut() {
                ls.copy_rows(src, dst, n_kv_heads, tokens);
            }
        }
    }

    /// Record that rows `[0, upto)` of `session` have been written (the
    /// serving backend reports prefill progress here).  Powers the
    /// debug-time readiness tripwire below; a no-op for accounting.
    pub fn note_filled(&mut self, session: u64, upto: usize) {
        if let Some(alloc) = self.tables.get_mut(&session) {
            alloc.filled = alloc.filled.max(upto);
        }
    }

    /// Debug tripwire for the cross-module safety argument: sharing is
    /// sound only because the scheduler's FIFO prefill runs a prefix
    /// registrant's writes before any sharer's first read.  Here — called
    /// ahead of each of `session`'s prefill chunks — every shared block
    /// whose registrant is still live must already be filled past that
    /// block.  A released registrant's rows are final, so it is skipped.
    /// Fires under a scheduler change that reorders or parallelises
    /// prefill across sessions instead of silently reading garbage.
    #[cfg(debug_assertions)]
    fn debug_assert_prefix_ready(&self, session: u64) {
        let Some(alloc) = self.tables.get(&session) else { return };
        for (i, &node) in alloc.trie_path[..alloc.shared_blocks].iter().enumerate() {
            let owner = self.trie.node_owner(node);
            if owner == session {
                continue;
            }
            if let Some(src) = self.tables.get(&owner) {
                debug_assert!(
                    src.filled >= (i + 1) * BLOCK_TOKENS,
                    "session {session} reads block {i} of prefix owner {owner}, \
                     which has only filled {} tokens",
                    src.filled
                );
            }
        }
        if let Some(cow) = &alloc.cow {
            if !cow.done && cow.src_session != session {
                if let Some(src) = self.tables.get(&cow.src_session) {
                    debug_assert!(
                        src.filled >= alloc.shared_blocks * BLOCK_TOKENS + cow.tokens,
                        "session {session} copies {} rows from owner {}, \
                         which has only filled {} tokens",
                        cow.tokens,
                        cow.src_session,
                        src.filled
                    );
                }
            }
        }
    }

    /// Grow `session`'s reservation so it covers at least `upto` *logical*
    /// tokens.  No-op when already covered (the coordinator reserves a
    /// request's full budget at admission, making per-step calls free on
    /// that path).  For a pressed (pruned) session, logical positions
    /// `[next_pos, upto)` each append one physical row at the tail of the
    /// compacted table.
    pub fn ensure_tokens(&mut self, session: u64, upto: usize) -> Result<()> {
        if self.tables.get(&session).is_some_and(|a| a.positions.is_some()) {
            return self.grow_pruned(session, upto);
        }
        let have = self.session_tokens(session);
        if upto > have {
            self.reserve(session, upto - have)
        } else {
            Ok(())
        }
    }

    /// Logical growth of a pruned session: one physical row per new
    /// logical position, appended in order at the compacted tail.
    fn grow_pruned(&mut self, session: u64, upto: usize) -> Result<()> {
        let (rows, have_blocks, next_pos) = {
            let a = &self.tables[&session];
            (a.tokens, a.blocks.len(), a.next_pos)
        };
        if upto <= next_pos {
            return Ok(());
        }
        let add = upto - next_pos;
        let deficit = (rows + add).div_ceil(BLOCK_TOKENS).saturating_sub(have_blocks);
        self.alloc_gate(deficit)?;
        self.clock += 1;
        for _ in 0..deficit {
            let block = self.take_free_block();
            self.tables.get_mut(&session).unwrap().blocks.push(block);
        }
        let a = self.tables.get_mut(&session).unwrap();
        let pv = a.positions.as_mut().unwrap();
        pv.extend((next_pos..upto).map(|p| p as u32));
        a.tokens = rows + add;
        a.next_pos = upto;
        if a.track_scores {
            a.row_scores.resize(rows + add, 0.0);
        }
        self.peak_used = self.peak_used.max(self.used_blocks());
        Ok(())
    }

    /// First reservation for a session resuming from a pressed (pruned)
    /// past life: one physical row per surviving logical position, plain
    /// allocation (no prefix sharing — compacted rows are not block-aligned
    /// prompt chunks).  `positions` must be strictly ascending.
    pub fn reserve_with_positions(&mut self, session: u64, positions: &[u32]) -> Result<()> {
        if self.tables.contains_key(&session) {
            bail!("session {session} already holds a reservation");
        }
        debug_assert!(positions.windows(2).all(|w| w[0] < w[1]));
        let rows = positions.len();
        let needed = rows.div_ceil(BLOCK_TOKENS);
        self.alloc_gate(needed)?;
        self.clock += 1;
        let mut blocks = Vec::with_capacity(needed);
        for _ in 0..needed {
            blocks.push(self.take_free_block());
        }
        let next_pos = positions.last().map(|&p| p as usize + 1).unwrap_or(0);
        self.tables.insert(
            session,
            SessionAlloc {
                blocks,
                tokens: rows,
                positions: Some(positions.to_vec()),
                next_pos,
                ..SessionAlloc::empty()
            },
        );
        self.peak_used = self.peak_used.max(self.used_blocks());
        Ok(())
    }

    /// Release a finished session's references: trie nodes deepest-first,
    /// then block refcounts.  A block returns to the free list (to be
    /// zeroed on its next reservation) only when its **last** reader
    /// releases — a shared prefix block outlives the session that created
    /// it for as long as any other session still reads it.
    ///
    /// With [`PagedKvCache::retain_cold_prefixes`] on, a trie node whose
    /// last holder leaves goes *cold* instead of being removed — provided
    /// its chunk's rows were actually written (`filled` covers it; a
    /// session torn down mid-prefill must not donate garbage rows to the
    /// cache).  The session's refcount on that block transfers to the
    /// cache, keeping the rows resident for future admissions until the
    /// evictor reclaims them under pressure.
    pub fn release(&mut self, session: u64) {
        self.clock += 1;
        if let Some(alloc) = self.tables.remove(&session) {
            // trie_path[i] pairs with blocks[i] (attached shared blocks
            // first, then self-registered prompt chunks, in chunk order).
            let mut kept = vec![false; alloc.trie_path.len()];
            for (i, &node) in alloc.trie_path.iter().enumerate().rev() {
                let chunk_written = alloc.filled >= (i + 1) * BLOCK_TOKENS;
                if self.retain_cold && chunk_written {
                    if self.trie.release_to_cold(node, self.clock) {
                        self.cold_blocks += 1;
                        kept[i] = true;
                    }
                } else {
                    self.trie.release(node);
                }
            }
            if let Some(cow) = alloc.cow {
                self.dec_block(cow.src_block);
            }
            for (i, &block) in alloc.blocks.iter().enumerate() {
                if i < kept.len() && kept[i] {
                    // Ownership moved to the cold cache with the node.
                    continue;
                }
                self.dec_block(block);
            }
        }
    }

    fn dec_block(&mut self, block: usize) {
        debug_assert!(self.refcount[block] > 0, "double free of block {block}");
        self.refcount[block] = self.refcount[block].saturating_sub(1);
        if self.refcount[block] == 0 {
            self.free.push(block);
        }
    }

    /// Live reader count of a physical block (0 = free).
    pub fn block_refs(&self, block: usize) -> u32 {
        self.refcount[block]
    }

    /// Distinct prompt chunks currently cached in the prefix trie
    /// (hot and cold).
    pub fn prefix_nodes(&self) -> usize {
        self.trie.len()
    }

    /// Prompt chunks resident only as cold (evictable) cache entries.
    pub fn cold_prefix_nodes(&self) -> usize {
        self.trie.cold_len()
    }

    /// Leading blocks `session` shares read-only with other readers.
    pub fn session_shared_blocks(&self, session: u64) -> usize {
        self.tables.get(&session).map(|t| t.shared_blocks).unwrap_or(0)
    }

    /// The block ids backing a session (page table), for diagnostics.
    pub fn page_table(&self, session: u64) -> Option<&[usize]> {
        self.tables.get(&session).map(|t| t.blocks.as_slice())
    }

    /// Logical sequence length of `session` — the number of positions its
    /// context represents, including pressed-out tokens.  Equals
    /// [`PagedKvCache::session_tokens`] until the first press.
    pub fn logical_tokens(&self, session: u64) -> usize {
        self.tables
            .get(&session)
            .map(|a| if a.positions.is_some() { a.next_pos } else { a.tokens })
            .unwrap_or(0)
    }

    /// The session's explicit logical→physical map, `None` while it is
    /// still the identity (retain-all).
    pub fn row_positions(&self, session: u64) -> Option<&[u32]> {
        self.tables.get(&session).and_then(|a| a.positions.as_deref())
    }

    /// Physical row currently holding logical position `pos`, if resident.
    pub fn row_index_of(&self, session: u64, pos: usize) -> Option<usize> {
        let a = self.tables.get(&session)?;
        match &a.positions {
            None => (pos < a.tokens).then_some(pos),
            Some(pv) => pv.binary_search(&(pos as u32)).ok(),
        }
    }

    /// Enable (or disable) per-row attention-mass accounting for
    /// `session` — the `AttnScore` press input.  Idempotent.
    pub fn set_score_tracking(&mut self, session: u64, on: bool) {
        if let Some(a) = self.tables.get_mut(&session) {
            a.track_scores = on;
            if on {
                a.row_scores.resize(a.tokens, 0.0);
            } else {
                a.row_scores = Vec::new();
            }
        }
    }

    /// Rows of `session` that a retention press must keep at their current
    /// (identity) slots: everything up to and including the last block
    /// shared through the prefix trie (refcount > 1).  Compaction never
    /// writes into a shared block, and rows past the last shared block can
    /// always compact into blocks this session owns exclusively.
    pub fn protected_rows(&self, session: u64) -> usize {
        let Some(a) = self.tables.get(&session) else { return 0 };
        let mut protected = 0;
        for (i, &b) in a.blocks.iter().enumerate() {
            if self.refcount[b] > 1 {
                protected = (i + 1) * BLOCK_TOKENS;
            }
        }
        // A pending copy-on-write destination also pins its block: the
        // copy targets fixed slots.
        if let Some(c) = &a.cow {
            if !c.done {
                protected = protected.max((c.dst_index + 1) * BLOCK_TOKENS);
            }
        }
        protected.min(a.tokens)
    }

    /// Rows evicted by retention presses so far (cumulative, all sessions).
    pub fn evicted_tokens(&self) -> u64 {
        self.evicted_rows
    }

    /// Retention presses that evicted at least one row.
    pub fn presses(&self) -> u64 {
        self.presses
    }

    /// Physical token rows resident across all live sessions (the
    /// "retained tokens" gauge: logical minus evicted).
    pub fn resident_rows(&self) -> usize {
        self.tables.values().map(|a| a.tokens).sum()
    }

    /// Sum over layers of each row's squared key L2 norm — the `L2Norm`
    /// press criterion (low-norm keys attract attention and are kept).
    /// Packed rows are dequantized into a scratch row first.
    pub fn row_key_norms(&mut self, session: u64) -> Vec<f32> {
        let rows = self.session_tokens(session);
        let mut out = vec![0.0f32; rows];
        if rows == 0 || self.store.is_none() {
            return out;
        }
        let (n_layers, n_kv_heads) = (self.shape.n_layers, self.shape.n_kv_heads);
        let max_kw = self.shape.k_width.iter().copied().max().unwrap_or(0);
        let mut scratch = vec![0.0f32; max_kw];
        let Ok((pages, store)) = self.tables_and_ptrs() else { return out };
        let Some(sv) = pages.view(session) else { return out };
        for l in 0..n_layers {
            // SAFETY: read-only sweep under the exclusive cache borrow.
            let view = unsafe { store.session_layer(l, &sv) };
            let kw = view.k_width;
            for hd in 0..n_kv_heads {
                if view.packed_q4() {
                    view.for_k_runs_q4(hd, rows, |t0, bytes| {
                        let rb = bytes.len() / (rows - t0).min(BLOCK_TOKENS);
                        for (j, row) in bytes.chunks_exact(rb).enumerate() {
                            quant::dequantize_row(row, &mut scratch[..kw]);
                            out[t0 + j] += scratch[..kw].iter().map(|x| x * x).sum::<f32>();
                        }
                    });
                } else {
                    view.for_k_runs(hd, rows, |t0, run| {
                        for (j, row) in run.chunks_exact(kw).enumerate() {
                            out[t0 + j] += row.iter().map(|x| x * x).sum::<f32>();
                        }
                    });
                }
            }
        }
        out
    }

    /// Compact `session` down to the rows in `keep` (strictly ascending
    /// physical row indices).  Surviving rows slide forward in place,
    /// their logical RoPE positions move with them, fully drained blocks
    /// return to the free pool, and trie registrations past the preserved
    /// identity prefix are dropped (their blocks' rows are stale after
    /// compaction).  The caller (the press planner) must keep every
    /// protected row — see [`PagedKvCache::protected_rows`].
    pub fn apply_retention(&mut self, session: u64, keep: &[usize]) -> Result<()> {
        let Some(a) = self.tables.get(&session) else {
            bail!("apply_retention on unknown session {session}")
        };
        if a.cow.as_ref().is_some_and(|c| !c.done) {
            bail!("retention press on session {session} before its copy-on-write resolved");
        }
        let rows = a.tokens;
        if keep.last().is_some_and(|&r| r >= rows) || keep.windows(2).any(|w| w[0] >= w[1]) {
            bail!("retention keep set must be strictly ascending rows below {rows}");
        }
        let protected = self.protected_rows(session);
        if keep.len() < protected || keep.iter().take(protected).enumerate().any(|(j, &r)| r != j) {
            bail!(
                "retention keep set evicts protected rows (first {protected} must survive in place)"
            );
        }
        if keep.len() == rows {
            return Ok(());
        }
        let n_kv_heads = self.shape.n_kv_heads;
        let mut a = self.tables.remove(&session).unwrap();
        // Forward in-place row moves: dest j <= keep[j] and every earlier
        // dest is strictly below the current source, so no read ever sees
        // an overwritten row.
        if let Some(store) = &mut self.store {
            for (j, &src) in keep.iter().enumerate() {
                if src == j {
                    continue;
                }
                let (sb, ss) = (a.blocks[src / BLOCK_TOKENS], src % BLOCK_TOKENS);
                let (db, ds) = (a.blocks[j / BLOCK_TOKENS], j % BLOCK_TOKENS);
                for ls in store.iter_mut() {
                    ls.copy_row(sb, ss, db, ds, n_kv_heads);
                }
            }
        }
        // Logical positions ride along with their rows.
        let old_pos = a.positions.take();
        if old_pos.is_none() {
            a.next_pos = rows;
        }
        a.positions = Some(
            keep.iter()
                .map(|&i| old_pos.as_ref().map(|p| p[i]).unwrap_or(i as u32))
                .collect(),
        );
        if a.track_scores && !a.row_scores.is_empty() {
            let old = std::mem::take(&mut a.row_scores);
            a.row_scores = keep.iter().map(|&i| old[i]).collect();
        }
        a.filled = keep.partition_point(|&r| r < a.filled);
        // Trie nodes whose chunks are no longer verbatim resident must go:
        // a future admission matching them would attach compacted rows.
        let ident = keep.iter().enumerate().take_while(|&(j, &r)| r == j).count();
        let preserved_chunks = ident / BLOCK_TOKENS;
        while a.trie_path.len() > preserved_chunks {
            let node = a.trie_path.pop().unwrap();
            self.trie.release(node);
        }
        a.shared_blocks = a.shared_blocks.min(preserved_chunks);
        a.tokens = keep.len();
        let needed = a.tokens.div_ceil(BLOCK_TOKENS);
        while a.blocks.len() > needed {
            let block = a.blocks.pop().unwrap();
            self.dec_block(block);
        }
        self.presses += 1;
        self.evicted_rows += (rows - keep.len()) as u64;
        self.tables.insert(session, a);
        Ok(())
    }

    /// Drop `session`'s trailing rows so exactly `keep_rows` remain — the
    /// speculative-decode rollback path.  A rejected draft leaves KV rows
    /// at the table's tail holding tokens that were never emitted; this
    /// truncates them, returns fully drained blocks to the pool, and
    /// clamps the written watermark, restoring the footprint the session
    /// would have had without the draft.
    ///
    /// Unlike [`PagedKvCache::apply_retention`] this never moves a row,
    /// never flips an identity session to an explicit position map, and
    /// never touches the press counters: it is pure tail rollback.  The
    /// tail being dropped is always session-private decode territory, so
    /// shared prefix blocks, trie registrations, and pending
    /// copy-on-write destinations must all sit below `keep_rows` — bailed
    /// on otherwise.  Steady state allocates nothing.
    pub fn truncate_rows(&mut self, session: u64, keep_rows: usize) -> Result<()> {
        let Some(a) = self.tables.get_mut(&session) else {
            bail!("truncate_rows on unknown session {session}")
        };
        let rows = a.tokens;
        if keep_rows > rows {
            bail!("truncate_rows({keep_rows}) beyond session {session}'s {rows} resident rows");
        }
        if keep_rows == rows {
            return Ok(());
        }
        let needed = keep_rows.div_ceil(BLOCK_TOKENS);
        if needed < a.trie_path.len() || needed < a.shared_blocks {
            bail!("truncate_rows would drop shared prefix blocks of session {session}");
        }
        if a.cow.as_ref().is_some_and(|c| !c.done && needed <= c.dst_index) {
            bail!("truncate_rows would drop session {session}'s pending copy-on-write block");
        }
        a.tokens = keep_rows;
        a.filled = a.filled.min(keep_rows);
        if let Some(pv) = a.positions.as_mut() {
            pv.truncate(keep_rows);
            a.next_pos = pv.last().map(|&p| p as usize + 1).unwrap_or(0);
        }
        if a.track_scores {
            a.row_scores.truncate(keep_rows);
        }
        // End the per-session borrow before touching the refcounts.
        let extra = a.blocks.len().saturating_sub(needed);
        for _ in 0..extra {
            let block = self
                .tables
                .get_mut(&session)
                .and_then(|a| a.blocks.pop())
                .expect("tail block present");
            self.dec_block(block);
        }
        Ok(())
    }

    /// Run a retention press over `session`: plan a keep set under `spec`
    /// (budget, protected prefix, unwritten rows and the recency window
    /// all honoured) and compact if it evicts anything.  `written_upto` is
    /// the logical position below which rows have been written (mid-prefill
    /// presses must not evict-or-move rows prefill has yet to fill).
    /// Returns the number of rows evicted; 0 on accounting-only caches.
    pub fn apply_press(
        &mut self,
        session: u64,
        spec: &retention::RetentionSpec,
        written_upto: usize,
    ) -> Result<usize> {
        if self.store.is_none() {
            return Ok(0);
        }
        let Some(a) = self.tables.get(&session) else { return Ok(0) };
        if a.cow.as_ref().is_some_and(|c| !c.done) {
            return Ok(0);
        }
        let rows = a.tokens;
        let logical = if a.positions.is_some() { a.next_pos } else { rows };
        if !retention::press_due(spec, rows, logical) {
            return Ok(0);
        }
        let written_rows = match &a.positions {
            None => written_upto.min(rows),
            Some(pv) => pv.partition_point(|&p| (p as usize) < written_upto),
        };
        let protected = self.protected_rows(session);
        let norms = if spec.press == retention::Press::L2Norm {
            self.row_key_norms(session)
        } else {
            Vec::new()
        };
        let a = self.tables.get(&session).unwrap();
        let keep = {
            let inputs = retention::PressInputs {
                rows,
                written_rows,
                protected_rows: protected,
                logical_len: logical,
                positions: a.positions.as_deref(),
                scores: if a.track_scores { &a.row_scores } else { &[] },
                key_norms: &norms,
                session,
            };
            retention::plan_keep(spec, &inputs)
        };
        let Some(keep) = keep else { return Ok(0) };
        let evicted = rows - keep.len();
        if evicted == 0 {
            return Ok(0);
        }
        self.apply_retention(session, &keep)?;
        Ok(evicted)
    }

    /// Split into the page-table read view and the raw storage handles the
    /// engine decodes through.  Errors on an accounting-only cache.
    ///
    /// Taking `&mut self` makes the returned handles the only live access
    /// path to the storage; per-session write disjointness is then
    /// guaranteed by block ownership (see [`StorePtrs::seq_layer`]).
    pub fn tables_and_ptrs(&mut self) -> Result<(PageTables<'_>, StorePtrs<'_>)> {
        // Refresh every tracked session's score-sink pointer: `row_scores`
        // may have been resized/compacted since the last decode.
        for a in self.tables.values_mut() {
            a.scores_ptr = if a.track_scores {
                a.row_scores.as_mut_ptr()
            } else {
                std::ptr::null_mut()
            };
        }
        let Some(store) = &self.store else {
            bail!("PagedKvCache was built accounting-only (use with_storage for engine decode)")
        };
        Ok((
            PageTables { tables: &self.tables },
            StorePtrs {
                layers: store.as_slice(),
                n_kv_heads: self.shape.n_kv_heads,
                _excl: PhantomData,
            },
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn shape(k: usize, v: usize) -> CacheShape {
        CacheShape {
            n_layers: 4,
            n_kv_heads: 2,
            k_width: vec![k; 4],
            v_width: vec![v; 4],
        }
    }

    #[test]
    fn bytes_accounting() {
        let s = shape(24, 24);
        // 2 heads * (24+24) * 4 layers = 384 floats/token
        assert_eq!(s.floats_per_token(), 384);
        assert_eq!(s.bytes_per_token(), 1536);
        assert_eq!(s.bytes_per_block(), 1536 * BLOCK_TOKENS);
        assert_eq!(s.bytes_for_tokens(10), 15360);
        assert_eq!(s.layer_floats_per_token(0), 96);
    }

    #[test]
    fn compressed_fits_proportionally_more() {
        // The deployability claim: at rho=30% the same byte budget holds
        // ~1/0.7x the tokens.
        let budget = 1 << 20;
        let full = PagedKvCache::new(shape(24, 24), budget);
        let rap = PagedKvCache::new(shape(16, 18), budget); // ~70.8% widths
        let gain = rap.free_token_capacity() as f64 / full.free_token_capacity() as f64;
        assert!(gain > 1.3 && gain < 1.55, "gain {gain}");
    }

    #[test]
    fn reserve_release_cycle() {
        let mut c = PagedKvCache::new(shape(8, 8), 1 << 16);
        let cap = c.capacity_blocks();
        assert!(cap > 0);
        c.reserve(1, 20).unwrap(); // 2 blocks
        assert_eq!(c.used_blocks(), 2);
        c.reserve(1, 10).unwrap(); // 30 tokens -> 2 blocks still
        assert_eq!(c.used_blocks(), 2);
        c.reserve(1, 3).unwrap(); // 33 tokens -> 3 blocks
        assert_eq!(c.used_blocks(), 3);
        assert_eq!(c.session_tokens(1), 33);
        c.release(1);
        assert_eq!(c.used_blocks(), 0);
        assert_eq!(c.session_tokens(1), 0);
    }

    #[test]
    fn ensure_tokens_grows_only_the_deficit() {
        let mut c = PagedKvCache::new(shape(8, 8), 1 << 16);
        c.ensure_tokens(1, 20).unwrap();
        assert_eq!(c.session_tokens(1), 20);
        c.ensure_tokens(1, 12).unwrap(); // already covered
        assert_eq!(c.session_tokens(1), 20);
        c.ensure_tokens(1, 40).unwrap();
        assert_eq!(c.session_tokens(1), 40);
        assert_eq!(c.used_blocks(), 3);
    }

    #[test]
    fn exhaustion_is_an_error_not_a_panic() {
        let sh = shape(8, 8);
        let mut c = PagedKvCache::new(sh.clone(), sh.bytes_per_block() * 2);
        assert_eq!(c.capacity_blocks(), 2);
        c.reserve(1, BLOCK_TOKENS * 2).unwrap();
        assert!(c.reserve(2, 1).is_err());
        c.release(1);
        assert!(c.reserve(2, 1).is_ok());
    }

    #[test]
    fn failed_first_reserve_leaves_no_stale_entry() {
        let sh = shape(8, 8);
        let mut c = PagedKvCache::new(sh.clone(), sh.bytes_per_block() * 2);
        c.reserve(1, BLOCK_TOKENS * 2).unwrap();
        assert!(c.reserve(2, BLOCK_TOKENS).is_err());
        assert_eq!(c.sessions(), 1, "failed admission leaves no stale entry");
        // The admission retry path goes through reserve_prefix, which
        // refuses sessions that already hold a reservation — a stale empty
        // entry would wedge it forever.
        assert!(c.reserve_prefix(2, &[1, 2, 3], BLOCK_TOKENS).is_err(), "still exhausted");
        c.release(1);
        assert!(c.reserve_prefix(2, &[1, 2, 3], BLOCK_TOKENS).is_ok(), "retry succeeds");
    }

    #[test]
    fn peak_tracking() {
        let sh = shape(8, 8);
        let mut c = PagedKvCache::new(sh.clone(), sh.bytes_per_block() * 8);
        c.reserve(1, BLOCK_TOKENS * 3).unwrap();
        c.release(1);
        c.reserve(2, BLOCK_TOKENS).unwrap();
        assert_eq!(c.peak_used_blocks(), 3);
    }

    #[test]
    fn page_tables_disjoint() {
        let sh = shape(8, 8);
        let mut c = PagedKvCache::new(sh.clone(), sh.bytes_per_block() * 10);
        c.reserve(1, BLOCK_TOKENS * 2).unwrap();
        c.reserve(2, BLOCK_TOKENS * 2).unwrap();
        let t1: Vec<usize> = c.page_table(1).unwrap().to_vec();
        let t2: Vec<usize> = c.page_table(2).unwrap().to_vec();
        assert!(t1.iter().all(|b| !t2.contains(b)));
    }

    #[test]
    fn accounting_only_cache_refuses_storage_access() {
        let mut c = PagedKvCache::new(shape(8, 8), 1 << 16);
        assert!(!c.has_storage());
        assert!(c.tables_and_ptrs().is_err());
    }

    #[test]
    fn storage_rows_round_trip_across_block_boundaries() {
        let sh = shape(6, 4);
        let mut c = PagedKvCache::with_storage(sh.clone(), sh.bytes_per_block() * 8);
        c.reserve(7, BLOCK_TOKENS * 2 + 3).unwrap();
        // Write distinct rows at the block seam: BLOCK_TOKENS-1, BLOCK_TOKENS,
        // BLOCK_TOKENS+1 (plus 0 and the last covered token).
        let probes = [0usize, BLOCK_TOKENS - 1, BLOCK_TOKENS, BLOCK_TOKENS + 1, 2 * BLOCK_TOKENS + 2];
        {
            let (pages, store) = c.tables_and_ptrs().unwrap();
            let blocks = pages.blocks(7).unwrap();
            for l in 0..sh.n_layers {
                // SAFETY: one live view per session at a time.
                let mut view = unsafe { store.seq_layer(l, blocks) };
                for &t in &probes {
                    for hd in 0..sh.n_kv_heads {
                        let tag = (l * 1000 + hd * 100 + t) as f32;
                        for (j, x) in view.k_row_mut(hd, t).iter_mut().enumerate() {
                            *x = tag + j as f32;
                        }
                        for (j, x) in view.v_row_mut(hd, t).iter_mut().enumerate() {
                            *x = -(tag + j as f32);
                        }
                    }
                }
            }
        }
        let (pages, store) = c.tables_and_ptrs().unwrap();
        let blocks = pages.blocks(7).unwrap();
        for l in 0..sh.n_layers {
            let view = unsafe { store.seq_layer(l, blocks) };
            for &t in &probes {
                for hd in 0..sh.n_kv_heads {
                    let tag = (l * 1000 + hd * 100 + t) as f32;
                    let k: Vec<f32> = (0..sh.k_width[l]).map(|j| tag + j as f32).collect();
                    let v: Vec<f32> = (0..sh.v_width[l]).map(|j| -(tag + j as f32)).collect();
                    assert_eq!(view.k_row(hd, t), &k[..], "K l{l} h{hd} t{t}");
                    assert_eq!(view.v_row(hd, t), &v[..], "V l{l} h{hd} t{t}");
                }
            }
        }
    }

    #[test]
    fn runs_cover_rows_in_order_and_match_row_reads() {
        let sh = shape(6, 4);
        let mut c = PagedKvCache::with_storage(sh.clone(), sh.bytes_per_block() * 8);
        let s = BLOCK_TOKENS * 2 + 5;
        c.reserve(3, s).unwrap();
        {
            let (pages, store) = c.tables_and_ptrs().unwrap();
            let mut view = unsafe { store.seq_layer(1, pages.blocks(3).unwrap()) };
            for t in 0..s {
                view.k_row_mut(0, t)[0] = t as f32;
                view.v_row_mut(0, t)[0] = 2.0 * t as f32;
            }
        }
        let (pages, store) = c.tables_and_ptrs().unwrap();
        let view = unsafe { store.seq_layer(1, pages.blocks(3).unwrap()) };
        let mut next = 0usize;
        view.for_k_runs(0, s, |t0, rows| {
            assert_eq!(t0, next);
            let n = rows.len() / sh.k_width[1];
            assert!(n <= BLOCK_TOKENS);
            for (i, chunk) in rows.chunks_exact(sh.k_width[1]).enumerate() {
                assert_eq!(chunk[0], (t0 + i) as f32);
            }
            next += n;
        });
        assert_eq!(next, s);
        let mut seen = 0usize;
        view.for_v_runs(0, s, |t0, rows| {
            for (i, chunk) in rows.chunks_exact(sh.v_width[1]).enumerate() {
                assert_eq!(chunk[0], 2.0 * (t0 + i) as f32);
            }
            seen = t0 + rows.len() / sh.v_width[1];
        });
        assert_eq!(seen, s);
    }

    #[test]
    fn mut_runs_cover_chunks_starting_mid_block() {
        let sh = shape(6, 4);
        let mut c = PagedKvCache::with_storage(sh.clone(), sh.bytes_per_block() * 8);
        let total = BLOCK_TOKENS * 3;
        c.reserve(5, total).unwrap();
        let (pages, store) = c.tables_and_ptrs().unwrap();
        let mut view = unsafe { store.seq_layer(2, pages.blocks(5).unwrap()) };
        // Write a chunk that starts mid-block and crosses two block seams.
        let (t0, n) = (BLOCK_TOKENS - 3, BLOCK_TOKENS + 7);
        let mut starts = Vec::new();
        let mut covered = 0usize;
        view.for_k_runs_mut(0, t0, n, |run_t0, rows| {
            starts.push(run_t0);
            assert_eq!(run_t0, t0 + covered, "runs in ascending token order");
            let w = sh.k_width[2];
            for (i, chunk) in rows.chunks_exact_mut(w).enumerate() {
                chunk[0] = (run_t0 + i) as f32;
            }
            covered += rows.len() / w;
        });
        assert_eq!(covered, n);
        assert_eq!(starts[0], t0);
        // The first run stops at the block boundary.
        assert_eq!(starts[1], BLOCK_TOKENS);
        for t in t0..t0 + n {
            assert_eq!(view.k_row(0, t)[0], t as f32, "row {t} via row read");
        }
        // V visitor: same coverage, disjoint storage.
        let mut seen = 0usize;
        view.for_v_runs_mut(1, t0, n, |run_t0, rows| {
            let w = sh.v_width[2];
            for (i, chunk) in rows.chunks_exact_mut(w).enumerate() {
                chunk[1] = -((run_t0 + i) as f32);
            }
            seen += rows.len() / w;
        });
        assert_eq!(seen, n);
        assert_eq!(view.v_row(1, t0 + n - 1)[1], -((t0 + n - 1) as f32));
    }

    /// Byte prompt whose chunks are distinguishable: token = i * 7 + salt.
    fn ptokens(len: usize, salt: usize) -> Vec<u8> {
        (0..len).map(|i| ((i * 7 + salt * 131) % 251) as u8).collect()
    }

    /// Tag every reserved row of `session` so sharing/zeroing is visible.
    fn fill_rows(c: &mut PagedKvCache, session: u64, tokens: usize, tag: f32) {
        c.note_filled(session, tokens);
        let n_layers = c.shape.n_layers;
        let hkv = c.shape.n_kv_heads;
        let (pages, store) = c.tables_and_ptrs().unwrap();
        let blocks = pages.blocks(session).unwrap();
        for l in 0..n_layers {
            // SAFETY: one live view per session at a time.
            let mut view = unsafe { store.seq_layer(l, blocks) };
            for t in 0..tokens {
                for hd in 0..hkv {
                    view.k_row_mut(hd, t).fill(tag + t as f32);
                    view.v_row_mut(hd, t).fill(-(tag + t as f32));
                }
            }
        }
    }

    #[test]
    fn prefix_reservation_shares_blocks_and_counts_them_once() {
        let sh = shape(8, 8);
        let mut c = PagedKvCache::with_storage(sh.clone(), sh.bytes_per_block() * 32);
        let prompt = ptokens(BLOCK_TOKENS * 2 + 8, 1); // 2 full chunks + 8
        let total = prompt.len() + 8; // 3 blocks

        let r1 = c.reserve_prefix(1, &prompt, total).unwrap();
        assert_eq!(r1.matched_tokens, 0, "cold trie: no match");
        assert_eq!(c.used_blocks(), 3);
        assert_eq!(c.prefix_nodes(), 2, "both full chunks registered");
        fill_rows(&mut c, 1, prompt.len(), 100.0);

        let r2 = c.reserve_prefix(2, &prompt, total).unwrap();
        assert_eq!(r2.matched_tokens, BLOCK_TOKENS * 2);
        assert_eq!(r2.shared_blocks, 2);
        // Only the 1 unmatched block is newly allocated.
        assert_eq!(c.used_blocks(), 4);
        let t1 = c.page_table(1).unwrap().to_vec();
        let t2 = c.page_table(2).unwrap().to_vec();
        assert_eq!(t1[..2], t2[..2], "prefix blocks are the same physical blocks");
        assert_ne!(t1[2], t2[2], "suffix blocks are private");
        assert_eq!(c.block_refs(t1[0]), 2);
        assert_eq!(c.session_shared_blocks(2), 2);

        // Session 2 reads session 1's prefix rows through its own table.
        let (pages, store) = c.tables_and_ptrs().unwrap();
        let view = unsafe { store.seq_layer(0, pages.blocks(2).unwrap()) };
        assert!(view.k_row(0, 5).iter().all(|&x| x == 105.0));
        let want = -(100.0 + (BLOCK_TOKENS + 3) as f32);
        assert!(view.v_row(1, BLOCK_TOKENS + 3).iter().all(|&x| x == want));
    }

    #[test]
    fn shared_blocks_survive_first_release() {
        // Satellite: a shared block must never be zeroed or handed to the
        // free list while any session still references it — interleaved
        // shared-prefix sessions over the reserve/release cycle.
        let sh = shape(5, 5);
        let mut c = PagedKvCache::with_storage(sh.clone(), sh.bytes_per_block() * 8);
        let prompt = ptokens(BLOCK_TOKENS * 2, 2); // exactly 2 chunks
        let total = BLOCK_TOKENS * 2 + BLOCK_TOKENS; // 3 blocks

        c.reserve_prefix(1, &prompt, total).unwrap();
        fill_rows(&mut c, 1, prompt.len(), 40.0);
        // Aligned, fully matched prompt: capped to P-1 with a CoW block.
        let r2 = c.reserve_prefix(2, &prompt, total).unwrap();
        assert_eq!(r2.matched_tokens, BLOCK_TOKENS * 2 - 1);
        assert_eq!(r2.shared_blocks, 1);
        c.materialize_cow(2);
        let shared = c.page_table(1).unwrap()[0];
        assert_eq!(c.block_refs(shared), 2);

        // Creator leaves first: the shared block stays resident and keeps
        // its rows; only session 1's private blocks are recycled.
        let used_before = c.used_blocks();
        c.release(1);
        assert_eq!(c.block_refs(shared), 1);
        // Only session 1's private block is freed: the fully shared block
        // and the CoW source are both still read by session 2.
        assert_eq!(c.used_blocks(), used_before - 1);
        // Exhaust the free list: the shared block must not be handed out.
        while c.reserve(99, BLOCK_TOKENS).is_ok() {}
        assert!(!c.page_table(99).unwrap_or(&[]).contains(&shared));
        {
            let (pages, store) = c.tables_and_ptrs().unwrap();
            let view = unsafe { store.seq_layer(0, pages.blocks(2).unwrap()) };
            assert!(view.k_row(0, 3).iter().all(|&x| x == 43.0), "shared rows intact");
        }
        c.release(99);

        // Last reader leaves: the block is recycled and zeroed on reuse.
        c.release(2);
        assert_eq!(c.used_blocks(), 0);
        assert_eq!(c.prefix_nodes(), 0, "trie empties with its last holder");
        c.reserve(3, BLOCK_TOKENS * 2).unwrap();
        let (pages, store) = c.tables_and_ptrs().unwrap();
        let view = unsafe { store.seq_layer(0, pages.blocks(3).unwrap()) };
        for t in 0..BLOCK_TOKENS * 2 {
            assert!(view.k_row(0, t).iter().all(|&x| x == 0.0), "stale rows after recycle");
        }
    }

    #[test]
    fn cow_block_is_private_copy() {
        let sh = shape(6, 4);
        let mut c = PagedKvCache::with_storage(sh.clone(), sh.bytes_per_block() * 16);
        let prompt = ptokens(BLOCK_TOKENS * 2, 3); // aligned -> capped match
        c.reserve_prefix(1, &prompt, prompt.len() + 4).unwrap();
        fill_rows(&mut c, 1, prompt.len(), 7.0);

        let r2 = c.reserve_prefix(2, &prompt, prompt.len() + 4).unwrap();
        assert_eq!(r2.matched_tokens, BLOCK_TOKENS * 2 - 1);
        c.materialize_cow(2);
        c.materialize_cow(2); // idempotent
        let src = c.page_table(1).unwrap()[1];
        let dst = c.page_table(2).unwrap()[1];
        assert_ne!(src, dst, "partial block is a private copy");
        let last = BLOCK_TOKENS * 2 - 1;
        {
            // The copy carries the matched rows...
            let (pages, store) = c.tables_and_ptrs().unwrap();
            let mut view = unsafe { store.seq_layer(1, pages.blocks(2).unwrap()) };
            let t = BLOCK_TOKENS * 2 - 2; // inside the copied range
            assert!(view.k_row(0, t).iter().all(|&x| x == 7.0 + t as f32));
            // ...and writing the session's own final row does not touch
            // the shared source.
            view.k_row_mut(0, last).fill(555.0);
        }
        let (pages, store) = c.tables_and_ptrs().unwrap();
        let view1 = unsafe { store.seq_layer(1, pages.blocks(1).unwrap()) };
        assert!(
            view1.k_row(0, last).iter().all(|&x| x == 7.0 + last as f32),
            "source unperturbed"
        );
    }

    #[test]
    fn prefix_reservation_respects_capacity() {
        let sh = shape(8, 8);
        let mut c = PagedKvCache::with_storage(sh.clone(), sh.bytes_per_block() * 4);
        let prompt = ptokens(BLOCK_TOKENS * 2, 4);
        c.reserve_prefix(1, &prompt, BLOCK_TOKENS * 3).unwrap(); // 3 of 4 blocks
        // A sharer fits in the single free block: the aligned match is
        // capped to P-1, sharing 1 full block and CoW-copying the second.
        let r = c.reserve_prefix(2, &prompt, BLOCK_TOKENS * 2).unwrap();
        assert_eq!(r.shared_blocks, 1, "capped aligned match shares 1 full block");
        assert_eq!(c.used_blocks(), 4);
        // An unshareable request is refused without corrupting state.
        assert!(c.reserve_prefix(3, &ptokens(BLOCK_TOKENS, 9), BLOCK_TOKENS * 2).is_err());
        assert!(c.reserve_prefix(1, &prompt, BLOCK_TOKENS).is_err(), "double reservation refused");
        c.release(2);
        c.release(1);
        assert_eq!(c.used_blocks(), 0);
        assert_eq!(c.prefix_nodes(), 0);
    }

    #[test]
    fn cold_retention_keeps_chunks_for_revival() {
        let sh = shape(8, 8);
        let mut c = PagedKvCache::with_storage(sh.clone(), sh.bytes_per_block() * 32);
        c.retain_cold_prefixes(true);
        let prompt = ptokens(BLOCK_TOKENS * 2, 5); // 2 aligned chunks
        c.reserve_prefix(1, &prompt, BLOCK_TOKENS * 3).unwrap();
        fill_rows(&mut c, 1, prompt.len(), 50.0);
        c.release(1);
        // Blocks return to baseline (cold blocks are reclaimable, not
        // "used") while the chunks stay resident for revival.
        assert_eq!(c.used_blocks(), 0, "cold cache never counts as used");
        assert_eq!(c.cold_blocks(), 2);
        assert_eq!(c.prefix_nodes(), 2);
        assert_eq!(c.cold_prefix_nodes(), 2);

        // A new session with the same prompt revives the cache: aligned
        // full match capped to P-1 -> 1 shared block + CoW on the second.
        let r = c.reserve_prefix(2, &prompt, BLOCK_TOKENS * 3).unwrap();
        assert_eq!(r.matched_tokens, BLOCK_TOKENS * 2 - 1, "revived match");
        assert_eq!(r.shared_blocks, 1);
        assert_eq!(c.cold_prefix_nodes(), 1, "first chunk revived hot");
        c.materialize_cow(2);
        {
            let (pages, store) = c.tables_and_ptrs().unwrap();
            let view = unsafe { store.seq_layer(0, pages.blocks(2).unwrap()) };
            assert!(
                view.k_row(0, 3).iter().all(|&x| x == 53.0),
                "revived rows are the original session's rows"
            );
            let t = BLOCK_TOKENS + 2; // inside the CoW copy
            assert!(view.k_row(0, t).iter().all(|&x| x == 50.0 + t as f32));
        }
        c.release(2);
        assert_eq!(c.used_blocks(), 0, "baseline again after the reviver leaves");
        assert_eq!(c.cold_blocks(), 2, "chunks parked cold again");
    }

    #[test]
    fn cold_blocks_are_evicted_under_pressure() {
        let sh = shape(8, 8);
        let mut c = PagedKvCache::with_storage(sh.clone(), sh.bytes_per_block() * 4);
        c.retain_cold_prefixes(true);
        let prompt = ptokens(BLOCK_TOKENS * 2, 1);
        c.reserve_prefix(1, &prompt, BLOCK_TOKENS * 2).unwrap();
        fill_rows(&mut c, 1, prompt.len(), 9.0);
        c.release(1);
        assert_eq!(c.cold_blocks(), 2);
        assert_eq!(c.free_token_capacity(), 4 * BLOCK_TOKENS, "cold is reclaimable");
        // 3 blocks wanted, 2 free: the gate evicts the deepest cold leaf
        // first (the only evictable one), keeping the shallower chunk.
        c.reserve(9, BLOCK_TOKENS * 3).unwrap();
        assert_eq!(c.evictions(), 1);
        assert_eq!(c.cold_blocks(), 1);
        assert_eq!(c.used_blocks(), 3);
        assert_eq!(c.prefix_nodes(), 1, "shallow chunk survives");
        // Exhausting the rest evicts the survivor too before failing.
        c.reserve(9, BLOCK_TOKENS).unwrap();
        assert_eq!(c.evictions(), 2);
        assert_eq!(c.cold_blocks(), 0);
        assert!(c.reserve(10, BLOCK_TOKENS).is_err(), "genuinely exhausted now");
        c.release(9);
        assert_eq!(c.used_blocks(), 0);
    }

    #[test]
    fn unwritten_chunks_are_never_retained_cold() {
        // A session torn down mid-prefill must not donate chunks whose
        // rows were never written: a future admission would read garbage.
        let sh = shape(8, 8);
        let mut c = PagedKvCache::with_storage(sh.clone(), sh.bytes_per_block() * 8);
        c.retain_cold_prefixes(true);
        let prompt = ptokens(BLOCK_TOKENS * 2, 7);
        c.reserve_prefix(1, &prompt, BLOCK_TOKENS * 2).unwrap();
        c.note_filled(1, BLOCK_TOKENS); // only the first chunk's rows exist
        c.release(1);
        assert_eq!(c.prefix_nodes(), 1, "written chunk retained");
        assert_eq!(c.cold_blocks(), 1);
        let r = c.reserve_prefix(2, &prompt, BLOCK_TOKENS * 2).unwrap();
        assert_eq!(r.matched_tokens, BLOCK_TOKENS, "only the written chunk matches");
        c.release(2);
    }

    #[test]
    fn retention_off_keeps_the_strict_release_model() {
        let sh = shape(8, 8);
        let mut c = PagedKvCache::with_storage(sh.clone(), sh.bytes_per_block() * 8);
        let prompt = ptokens(BLOCK_TOKENS * 2, 8);
        c.reserve_prefix(1, &prompt, BLOCK_TOKENS * 2).unwrap();
        fill_rows(&mut c, 1, prompt.len(), 3.0);
        c.release(1);
        assert_eq!(c.prefix_nodes(), 0, "default: trie empties with its last holder");
        assert_eq!(c.cold_blocks(), 0);
        assert_eq!(c.used_blocks(), 0);
    }

    #[test]
    fn injected_alloc_faults_are_typed_and_skip_zero_deficit_paths() {
        use crate::faults::{FaultPlan, InjectedFault};
        let sh = shape(8, 8);
        let mut c = PagedKvCache::new(sh.clone(), sh.bytes_per_block() * 8);
        c.set_alloc_faults(Some(FaultPlan::new(1).with_alloc_faults(1.0).alloc_injector()));
        let err = c.reserve(1, BLOCK_TOKENS).unwrap_err();
        assert!(
            err.downcast_ref::<InjectedFault>().is_some(),
            "typed fault, distinguishable from genuine exhaustion: {err}"
        );
        assert_eq!(c.alloc_faults_injected(), 1);
        assert_eq!(c.sessions(), 0, "failed first reservation leaves no entry");
        c.set_alloc_faults(None);
        c.reserve(1, BLOCK_TOKENS - 1).unwrap();
        c.set_alloc_faults(Some(FaultPlan::new(1).with_alloc_faults(1.0).alloc_injector()));
        // Growth inside the already-reserved block has zero deficit: the
        // (fresh) fault stream must not even be consulted.
        c.reserve(1, 1).unwrap();
        assert_eq!(c.alloc_faults_injected(), 0, "zero-deficit paths never draw");
        // The next block boundary does draw — and fails.
        assert!(c.reserve(1, BLOCK_TOKENS).is_err());
        assert_eq!(c.alloc_faults_injected(), 1);
        c.release(1);
    }

    #[test]
    fn truncate_rows_returns_drained_blocks_without_pressing() {
        let sh = shape(8, 8);
        let mut c = PagedKvCache::with_storage(sh.clone(), sh.bytes_per_block() * 8);
        c.reserve(1, BLOCK_TOKENS + 2).unwrap();
        let baseline = c.used_blocks();
        // A draft grows the tail by a couple of blocks...
        c.ensure_tokens(1, BLOCK_TOKENS * 3 + 4).unwrap();
        assert!(c.used_blocks() > baseline);
        // ...and rejection rolls it back exactly.
        c.truncate_rows(1, BLOCK_TOKENS + 2).unwrap();
        assert_eq!(c.used_blocks(), baseline);
        assert_eq!(c.session_tokens(1), BLOCK_TOKENS + 2);
        assert_eq!(c.logical_tokens(1), BLOCK_TOKENS + 2, "identity map survives");
        assert!(c.row_positions(1).is_none(), "no position map materialized");
        assert_eq!(c.presses(), 0, "rollback is not a press");
        assert_eq!(c.evicted_tokens(), 0);
        // Truncating to the current size is a no-op; overshooting bails.
        c.truncate_rows(1, BLOCK_TOKENS + 2).unwrap();
        assert!(c.truncate_rows(1, BLOCK_TOKENS * 4).is_err());
        assert!(c.truncate_rows(99, 0).is_err(), "unknown session");
        c.release(1);
        assert_eq!(c.used_blocks(), 0);
    }

    #[test]
    fn truncate_rows_on_a_pruned_session_restores_the_position_map() {
        let sh = shape(8, 8);
        let mut c = PagedKvCache::with_storage(sh.clone(), sh.bytes_per_block() * 16);
        let rows = BLOCK_TOKENS * 4;
        c.reserve(1, rows).unwrap();
        fill_rows(&mut c, 1, rows, 1.5);
        // Press out the middle so the session carries an explicit map.
        let keep: Vec<usize> = (0..8).chain(rows - 24..rows).collect();
        c.apply_retention(1, &keep).unwrap();
        let kept = keep.len();
        assert_eq!(c.session_tokens(1), kept);
        assert_eq!(c.logical_tokens(1), rows);
        let baseline = c.used_blocks();
        // Draft rows append at the tail with fresh logical positions...
        c.ensure_tokens(1, kept + 5).unwrap();
        assert_eq!(c.logical_tokens(1), rows + 5);
        // ...rollback drops them and restores next_pos from the survivors.
        c.truncate_rows(1, kept).unwrap();
        assert_eq!(c.used_blocks(), baseline);
        assert_eq!(c.session_tokens(1), kept);
        assert_eq!(c.logical_tokens(1), rows);
        let pv = c.row_positions(1).unwrap();
        assert_eq!(pv.len(), kept);
        assert_eq!(*pv.last().unwrap() as usize, rows - 1);
        c.release(1);
    }

    #[test]
    fn truncate_rows_refuses_to_drop_shared_prefix_blocks() {
        let sh = shape(8, 8);
        let mut c = PagedKvCache::with_storage(sh.clone(), sh.bytes_per_block() * 16);
        let prompt = ptokens(BLOCK_TOKENS * 2, 3);
        c.reserve_prefix(1, &prompt, prompt.len() + 4).unwrap();
        fill_rows(&mut c, 1, prompt.len(), 2.0);
        // A second session attaches the shared prefix read-only.
        c.reserve_prefix(2, &prompt, prompt.len() + 4).unwrap();
        assert!(
            c.truncate_rows(1, BLOCK_TOKENS).is_err(),
            "tail rollback must never reach into trie-registered blocks"
        );
        // The session's private tail can still roll back.
        c.truncate_rows(1, prompt.len() + 1).unwrap();
        assert_eq!(c.session_tokens(1), prompt.len() + 1);
        c.release(1);
        c.release(2);
    }

    #[test]
    fn no_stale_rows_across_reuse() {
        let sh = shape(5, 5);
        let mut c = PagedKvCache::with_storage(sh.clone(), sh.bytes_per_block() * 2);
        c.reserve(1, BLOCK_TOKENS * 2).unwrap();
        {
            let (pages, store) = c.tables_and_ptrs().unwrap();
            let blocks = pages.blocks(1).unwrap();
            for l in 0..sh.n_layers {
                // SAFETY: one live view per session at a time.
                let mut view = unsafe { store.seq_layer(l, blocks) };
                for t in 0..BLOCK_TOKENS * 2 {
                    for hd in 0..sh.n_kv_heads {
                        view.k_row_mut(hd, t).fill(9.25);
                        view.v_row_mut(hd, t).fill(-9.25);
                    }
                }
            }
        }
        c.release(1);
        // Session 2 must get the same physical blocks back, fully zeroed.
        c.reserve(2, BLOCK_TOKENS * 2).unwrap();
        let (pages, store) = c.tables_and_ptrs().unwrap();
        let blocks = pages.blocks(2).unwrap();
        for l in 0..sh.n_layers {
            let view = unsafe { store.seq_layer(l, blocks) };
            for t in 0..BLOCK_TOKENS * 2 {
                for hd in 0..sh.n_kv_heads {
                    assert!(view.k_row(hd, t).iter().all(|&x| x == 0.0), "stale K l{l} t{t}");
                    assert!(view.v_row(hd, t).iter().all(|&x| x == 0.0), "stale V l{l} t{t}");
                }
            }
        }
    }
}
