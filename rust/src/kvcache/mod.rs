//! Latent-width-aware paged KV-cache manager.
//!
//! The serving-side resource RAP compresses.  Sessions allocate cache space
//! in fixed-size token *blocks*; each layer's block holds
//! `n_kv_heads * block_tokens * (k_width + v_width)` floats, where the
//! widths come from the variant's pruning plan — so the *same allocator*
//! serves baseline and compressed models and its accounting directly
//! exhibits the paper's KV-cache reduction.
//!
//! `quant` adds int4 group quantization of latent rows (the Fig. 12
//! orthogonality experiment: RAP + 4-bit KV).

pub mod quant;

use std::collections::BTreeMap;

use anyhow::{bail, Result};

use crate::config::{ModelConfig, VariantSpec};

pub const BLOCK_TOKENS: usize = 16;

/// Static description of one variant's per-layer cache widths.
#[derive(Debug, Clone)]
pub struct CacheShape {
    pub n_layers: usize,
    pub n_kv_heads: usize,
    pub k_width: Vec<usize>,
    pub v_width: Vec<usize>,
}

impl CacheShape {
    pub fn of(cfg: &ModelConfig, spec: &VariantSpec) -> CacheShape {
        CacheShape {
            n_layers: cfg.n_layers,
            n_kv_heads: cfg.n_kv_heads,
            k_width: spec.k_rank.clone(),
            v_width: spec.v_rank.clone(),
        }
    }

    /// f32 count per cached token across all layers/heads.
    pub fn floats_per_token(&self) -> usize {
        self.n_kv_heads
            * (self.k_width.iter().sum::<usize>() + self.v_width.iter().sum::<usize>())
    }

    pub fn bytes_per_token(&self) -> usize {
        4 * self.floats_per_token()
    }

    pub fn bytes_per_block(&self) -> usize {
        self.bytes_per_token() * BLOCK_TOKENS
    }
}

/// Paged block allocator with per-session page tables.
///
/// Capacity is expressed in bytes (as an operator would configure it); the
/// block budget adapts to the variant's width, so a RAP-compressed model
/// fits proportionally more tokens in the same budget — the deployability
/// claim of the paper's introduction.
#[derive(Debug)]
pub struct PagedKvCache {
    pub shape: CacheShape,
    capacity_blocks: usize,
    free: Vec<usize>,
    /// session -> block ids (one entry per BLOCK_TOKENS tokens).
    tables: BTreeMap<u64, SessionAlloc>,
    peak_used: usize,
}

#[derive(Debug, Clone)]
struct SessionAlloc {
    blocks: Vec<usize>,
    tokens: usize,
}

impl PagedKvCache {
    pub fn new(shape: CacheShape, capacity_bytes: usize) -> PagedKvCache {
        let capacity_blocks = capacity_bytes / shape.bytes_per_block().max(1);
        PagedKvCache {
            shape,
            capacity_blocks,
            free: (0..capacity_blocks).rev().collect(),
            tables: BTreeMap::new(),
            peak_used: 0,
        }
    }

    pub fn capacity_blocks(&self) -> usize {
        self.capacity_blocks
    }

    pub fn used_blocks(&self) -> usize {
        self.capacity_blocks - self.free.len()
    }

    pub fn peak_used_blocks(&self) -> usize {
        self.peak_used
    }

    pub fn used_bytes(&self) -> usize {
        self.used_blocks() * self.shape.bytes_per_block()
    }

    /// Max tokens a fresh session could hold right now.
    pub fn free_token_capacity(&self) -> usize {
        self.free.len() * BLOCK_TOKENS
    }

    pub fn session_tokens(&self, session: u64) -> usize {
        self.tables.get(&session).map(|t| t.tokens).unwrap_or(0)
    }

    pub fn sessions(&self) -> usize {
        self.tables.len()
    }

    /// Reserve capacity for `tokens` more tokens of `session`, allocating
    /// blocks as needed.  Fails (backpressure signal) when out of blocks.
    pub fn reserve(&mut self, session: u64, tokens: usize) -> Result<()> {
        let entry = self
            .tables
            .entry(session)
            .or_insert(SessionAlloc { blocks: Vec::new(), tokens: 0 });
        let needed_tokens = entry.tokens + tokens;
        let needed_blocks = needed_tokens.div_ceil(BLOCK_TOKENS);
        let deficit = needed_blocks.saturating_sub(entry.blocks.len());
        if deficit > self.free.len() {
            bail!(
                "kv-cache exhausted: need {deficit} blocks, {} free (capacity {})",
                self.free.len(),
                self.capacity_blocks
            );
        }
        for _ in 0..deficit {
            entry.blocks.push(self.free.pop().unwrap());
        }
        entry.tokens = needed_tokens;
        self.peak_used = self.peak_used.max(self.capacity_blocks - self.free.len());
        Ok(())
    }

    /// Release a finished session's blocks.
    pub fn release(&mut self, session: u64) {
        if let Some(alloc) = self.tables.remove(&session) {
            self.free.extend(alloc.blocks);
        }
    }

    /// The block ids backing a session (page table), for diagnostics.
    pub fn page_table(&self, session: u64) -> Option<&[usize]> {
        self.tables.get(&session).map(|t| t.blocks.as_slice())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn shape(k: usize, v: usize) -> CacheShape {
        CacheShape {
            n_layers: 4,
            n_kv_heads: 2,
            k_width: vec![k; 4],
            v_width: vec![v; 4],
        }
    }

    #[test]
    fn bytes_accounting() {
        let s = shape(24, 24);
        // 2 heads * (24+24) * 4 layers = 384 floats/token
        assert_eq!(s.floats_per_token(), 384);
        assert_eq!(s.bytes_per_token(), 1536);
        assert_eq!(s.bytes_per_block(), 1536 * BLOCK_TOKENS);
    }

    #[test]
    fn compressed_fits_proportionally_more() {
        // The deployability claim: at rho=30% the same byte budget holds
        // ~1/0.7x the tokens.
        let budget = 1 << 20;
        let full = PagedKvCache::new(shape(24, 24), budget);
        let rap = PagedKvCache::new(shape(16, 18), budget); // ~70.8% widths
        let gain = rap.free_token_capacity() as f64 / full.free_token_capacity() as f64;
        assert!(gain > 1.3 && gain < 1.55, "gain {gain}");
    }

    #[test]
    fn reserve_release_cycle() {
        let mut c = PagedKvCache::new(shape(8, 8), 1 << 16);
        let cap = c.capacity_blocks();
        assert!(cap > 0);
        c.reserve(1, 20).unwrap(); // 2 blocks
        assert_eq!(c.used_blocks(), 2);
        c.reserve(1, 10).unwrap(); // 30 tokens -> 2 blocks still
        assert_eq!(c.used_blocks(), 2);
        c.reserve(1, 3).unwrap(); // 33 tokens -> 3 blocks
        assert_eq!(c.used_blocks(), 3);
        assert_eq!(c.session_tokens(1), 33);
        c.release(1);
        assert_eq!(c.used_blocks(), 0);
        assert_eq!(c.session_tokens(1), 0);
    }

    #[test]
    fn exhaustion_is_an_error_not_a_panic() {
        let sh = shape(8, 8);
        let mut c = PagedKvCache::new(sh.clone(), sh.bytes_per_block() * 2);
        assert_eq!(c.capacity_blocks(), 2);
        c.reserve(1, BLOCK_TOKENS * 2).unwrap();
        assert!(c.reserve(2, 1).is_err());
        c.release(1);
        assert!(c.reserve(2, 1).is_ok());
    }

    #[test]
    fn peak_tracking() {
        let sh = shape(8, 8);
        let mut c = PagedKvCache::new(sh.clone(), sh.bytes_per_block() * 8);
        c.reserve(1, BLOCK_TOKENS * 3).unwrap();
        c.release(1);
        c.reserve(2, BLOCK_TOKENS).unwrap();
        assert_eq!(c.peak_used_blocks(), 3);
    }

    #[test]
    fn page_tables_disjoint() {
        let sh = shape(8, 8);
        let mut c = PagedKvCache::new(sh.clone(), sh.bytes_per_block() * 10);
        c.reserve(1, BLOCK_TOKENS * 2).unwrap();
        c.reserve(2, BLOCK_TOKENS * 2).unwrap();
        let t1: Vec<usize> = c.page_table(1).unwrap().to_vec();
        let t2: Vec<usize> = c.page_table(2).unwrap().to_vec();
        assert!(t1.iter().all(|b| !t2.contains(b)));
    }
}
