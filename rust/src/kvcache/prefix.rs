//! Content-addressed prefix trie over block-aligned token chunks.
//!
//! Maps `BLOCK_TOKENS`-sized prompt chunks to the physical blocks holding
//! their latent K/V rows, so concurrent requests with a common prompt
//! prefix can *share* those blocks instead of recomputing and re-storing
//! them — the serving-side multiplier on RAP's per-row compression.
//!
//! Lifetime model: a node exists only while at least one live session
//! holds a reference on it — the session that registered the chunk (its
//! own prompt block) or any session that matched it at admission and
//! attached.  Releasing the last reference removes the node, and because
//! every holder also holds a refcount on the node's physical block
//! (`PagedKvCache` pairs the two), the trie can never point at a block
//! that has been recycled.  Retaining nodes beyond the last session —
//! with eviction of cold entries — is the follow-on in ROADMAP.md.
//!
//! Removal is always deepest-first (sessions release their path in
//! reverse): any live descendant of a node implies a session holding the
//! whole path through that node, so a node whose refcount reaches zero
//! has no children left.

use std::collections::BTreeMap;

use crate::kvcache::BLOCK_TOKENS;

/// Index of the (empty-prefix) root node.  The root carries no chunk or
/// block and is never removed.
pub const ROOT: usize = 0;

#[derive(Debug)]
struct Node {
    /// Child nodes keyed by the next `BLOCK_TOKENS` prompt tokens.
    children: BTreeMap<Vec<u8>, usize>,
    /// Physical block holding this chunk's latent K/V rows.
    block: usize,
    /// Live sessions holding this node (registrant + attachers).
    refs: usize,
    /// Session that registered the chunk — the one whose prefill writes
    /// the block's rows (used by debug-time readiness checks).
    owner: u64,
    parent: usize,
    /// This node's key in `parent.children` (for unlinking on removal).
    key: Vec<u8>,
    live: bool,
}

/// Trie over block-aligned token prefixes; see the module docs.
#[derive(Debug)]
pub struct PrefixTrie {
    /// Node arena; slot 0 is the root, dead slots are recycled via `free`.
    nodes: Vec<Node>,
    free: Vec<usize>,
    live_count: usize,
}

impl Default for PrefixTrie {
    fn default() -> Self {
        PrefixTrie::new()
    }
}

impl PrefixTrie {
    pub fn new() -> PrefixTrie {
        PrefixTrie {
            nodes: vec![Node {
                children: BTreeMap::new(),
                block: usize::MAX,
                refs: 0,
                owner: u64::MAX,
                parent: ROOT,
                key: Vec::new(),
                live: true,
            }],
            free: Vec::new(),
            live_count: 0,
        }
    }

    /// Walk the full `BLOCK_TOKENS` chunks of `prompt`, returning the
    /// longest cached path as `(node, block)` pairs in prefix order.  A
    /// trailing partial chunk never matches (blocks are shared whole).
    pub fn lookup(&self, prompt: &[u8]) -> Vec<(usize, usize)> {
        let mut path = Vec::new();
        let mut at = ROOT;
        for chunk in prompt.chunks_exact(BLOCK_TOKENS) {
            match self.nodes[at].children.get(chunk) {
                Some(&next) => {
                    path.push((next, self.nodes[next].block));
                    at = next;
                }
                None => break,
            }
        }
        path
    }

    /// Take one reference on `node` (a session now shares its block).
    pub fn attach(&mut self, node: usize) {
        debug_assert!(self.nodes[node].live, "attach to dead node {node}");
        self.nodes[node].refs += 1;
    }

    /// Insert `chunk` below `parent` pointing at `block`, registered by
    /// session `owner`, with one reference held by it; returns the node
    /// index.  If the child already exists it is attached instead and
    /// keeps its original block and owner (the caller keeps its own copy
    /// in its page table).
    pub fn insert_child(&mut self, parent: usize, chunk: &[u8], block: usize, owner: u64) -> usize {
        if let Some(&existing) = self.nodes[parent].children.get(chunk) {
            self.attach(existing);
            return existing;
        }
        let node = Node {
            children: BTreeMap::new(),
            block,
            refs: 1,
            owner,
            parent,
            key: chunk.to_vec(),
            live: true,
        };
        let idx = match self.free.pop() {
            Some(i) => {
                self.nodes[i] = node;
                i
            }
            None => {
                self.nodes.push(node);
                self.nodes.len() - 1
            }
        };
        self.nodes[parent].children.insert(chunk.to_vec(), idx);
        self.live_count += 1;
        idx
    }

    /// Drop one reference on `node`, removing it when the last holder
    /// leaves.  Callers release a session's path deepest-first.
    pub fn release(&mut self, node: usize) {
        debug_assert!(node != ROOT, "release of the trie root");
        debug_assert!(
            self.nodes[node].live && self.nodes[node].refs > 0,
            "release of dead/unreferenced node {node}"
        );
        self.nodes[node].refs -= 1;
        if self.nodes[node].refs == 0 {
            debug_assert!(
                self.nodes[node].children.is_empty(),
                "removed trie node {node} still has children"
            );
            let parent = self.nodes[node].parent;
            let key = std::mem::take(&mut self.nodes[node].key);
            self.nodes[parent].children.remove(&key);
            self.nodes[node].live = false;
            self.nodes[node].children.clear();
            self.free.push(node);
            self.live_count -= 1;
        }
    }

    /// Session whose prefill produces (or produced) `node`'s rows.
    pub fn node_owner(&self, node: usize) -> u64 {
        self.nodes[node].owner
    }

    /// Live (non-root) nodes — the number of distinct cached chunks.
    pub fn len(&self) -> usize {
        self.live_count
    }

    pub fn is_empty(&self) -> bool {
        self.live_count == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn chunk(tag: u8) -> Vec<u8> {
        vec![tag; BLOCK_TOKENS]
    }

    fn prompt(tags: &[u8], tail: usize) -> Vec<u8> {
        let mut p: Vec<u8> = tags.iter().flat_map(|&t| chunk(t)).collect();
        p.extend(std::iter::repeat(0xEE).take(tail));
        p
    }

    #[test]
    fn lookup_matches_longest_full_block_prefix() {
        let mut t = PrefixTrie::new();
        let a = t.insert_child(ROOT, &chunk(1), 10, 1);
        let b = t.insert_child(a, &chunk(2), 11, 1);
        assert_eq!(t.len(), 2);
        // Full match of both chunks; the 5-token tail can't match.
        assert_eq!(t.lookup(&prompt(&[1, 2], 5)), vec![(a, 10), (b, 11)]);
        // Diverging second chunk stops after the first.
        assert_eq!(t.lookup(&prompt(&[1, 3], 0)), vec![(a, 10)]);
        // A sub-block prompt never matches.
        assert_eq!(t.lookup(&[1u8; BLOCK_TOKENS - 1]), vec![]);
    }

    #[test]
    fn release_removes_only_unreferenced_nodes_deepest_first() {
        let mut t = PrefixTrie::new();
        let a = t.insert_child(ROOT, &chunk(1), 10, 1);
        let b = t.insert_child(a, &chunk(2), 11, 1);
        // A second session matches both chunks and attaches.
        t.attach(a);
        t.attach(b);
        // First session leaves: nodes survive on the second's refs.
        t.release(b);
        t.release(a);
        assert_eq!(t.len(), 2);
        assert_eq!(t.lookup(&prompt(&[1, 2], 0)).len(), 2);
        // Second session leaves: the whole path dies.
        t.release(b);
        t.release(a);
        assert!(t.is_empty());
        assert_eq!(t.lookup(&prompt(&[1, 2], 0)), vec![]);
    }

    #[test]
    fn node_slots_are_recycled() {
        let mut t = PrefixTrie::new();
        let a = t.insert_child(ROOT, &chunk(1), 10, 1);
        t.release(a);
        let b = t.insert_child(ROOT, &chunk(2), 20, 2);
        assert_eq!(a, b, "dead slot reused");
        assert_eq!(t.lookup(&prompt(&[2], 0)), vec![(b, 20)]);
    }

    #[test]
    fn duplicate_insert_attaches_existing_node() {
        let mut t = PrefixTrie::new();
        let a = t.insert_child(ROOT, &chunk(1), 10, 1);
        let same = t.insert_child(ROOT, &chunk(1), 99, 2);
        assert_eq!(a, same);
        assert_eq!(t.lookup(&prompt(&[1], 0)), vec![(a, 10)], "original block kept");
        t.release(a);
        assert_eq!(t.len(), 1, "second reference keeps the node alive");
        t.release(a);
        assert!(t.is_empty());
    }
}
