//! Content-addressed prefix trie over block-aligned token chunks.
//!
//! Maps `BLOCK_TOKENS`-sized prompt chunks to the physical blocks holding
//! their latent K/V rows, so concurrent requests with a common prompt
//! prefix can *share* those blocks instead of recomputing and re-storing
//! them — the serving-side multiplier on RAP's per-row compression.
//!
//! Lifetime model: a node is *hot* while at least one live session holds
//! a reference on it — the session that registered the chunk (its own
//! prompt block) or any session that matched it at admission and
//! attached.  Because every holder also holds a refcount on the node's
//! physical block (`PagedKvCache` pairs the two), the trie can never
//! point at a block that has been recycled.
//!
//! When the last holder leaves there are two paths:
//!
//! * [`PrefixTrie::release`] — remove the node immediately (the original
//!   lifetime model; still used when cold retention is off, and always
//!   used for chunks whose rows were never fully written);
//! * [`PrefixTrie::release_to_cold`] — keep the node resident as a *cold*
//!   entry (refs == 0, still linked and matchable).  The owning
//!   `PagedKvCache` transfers the departing session's block refcount to
//!   the cache itself, so the block stays out of the free list while
//!   cold.  A later admission that matches the chunk revives it
//!   ([`PrefixTrie::attach`] returns `true`), handing the hold back to a
//!   session; under memory pressure the evictor removes cold leaves
//!   ([`PrefixTrie::best_eviction`] / [`PrefixTrie::evict`]) —
//!   least-recently-cooled first, scaled by recompute cost (depth: a
//!   deeper chunk needs its whole prefix re-prefilled to come back).
//!
//! Removal is always deepest-first (sessions release their path in
//! reverse): any live descendant of a node implies a session holding the
//! whole path through that node, so a node whose refcount reaches zero
//! has no children left.  Cold nodes may *gain* children while cold (a
//! reviving session registers deeper chunks), which is why only cold
//! leaves are evictable — evicting a mid-path node would orphan the
//! descendants' lookup path.

use std::collections::BTreeMap;

use crate::kvcache::BLOCK_TOKENS;

/// Index of the (empty-prefix) root node.  The root carries no chunk or
/// block and is never removed.
pub const ROOT: usize = 0;

#[derive(Debug)]
struct Node {
    /// Child nodes keyed by the next `BLOCK_TOKENS` prompt tokens.
    children: BTreeMap<Vec<u8>, usize>,
    /// Physical block holding this chunk's latent K/V rows.
    block: usize,
    /// Live sessions holding this node (registrant + attachers).
    refs: usize,
    /// Session that registered the chunk — the one whose prefill writes
    /// the block's rows (used by debug-time readiness checks).
    owner: u64,
    parent: usize,
    /// This node's key in `parent.children` (for unlinking on removal).
    key: Vec<u8>,
    live: bool,
    /// refs == 0 but retained as an evictable cache entry.
    cold: bool,
    /// Logical tick at which the node last went cold (LRU key for the
    /// evictor; never wall time, so eviction order is deterministic).
    cooled_at: u64,
    /// Chunks from the root (1 for a top-level chunk) — the recompute
    /// cost proxy: reviving a depth-d chunk from scratch means
    /// re-prefilling d blocks of prompt.
    depth: usize,
}

/// Trie over block-aligned token prefixes; see the module docs.
#[derive(Debug)]
pub struct PrefixTrie {
    /// Node arena; slot 0 is the root, dead slots are recycled via `free`.
    nodes: Vec<Node>,
    free: Vec<usize>,
    live_count: usize,
    cold_count: usize,
}

impl Default for PrefixTrie {
    fn default() -> Self {
        PrefixTrie::new()
    }
}

impl PrefixTrie {
    pub fn new() -> PrefixTrie {
        PrefixTrie {
            nodes: vec![Node {
                children: BTreeMap::new(),
                block: usize::MAX,
                refs: 0,
                owner: u64::MAX,
                parent: ROOT,
                key: Vec::new(),
                live: true,
                cold: false,
                cooled_at: 0,
                depth: 0,
            }],
            free: Vec::new(),
            live_count: 0,
            cold_count: 0,
        }
    }

    /// Walk the full `BLOCK_TOKENS` chunks of `prompt`, returning the
    /// longest cached path as `(node, block)` pairs in prefix order.  A
    /// trailing partial chunk never matches (blocks are shared whole).
    pub fn lookup(&self, prompt: &[u8]) -> Vec<(usize, usize)> {
        let mut path = Vec::new();
        let mut at = ROOT;
        for chunk in prompt.chunks_exact(BLOCK_TOKENS) {
            match self.nodes[at].children.get(chunk) {
                Some(&next) => {
                    path.push((next, self.nodes[next].block));
                    at = next;
                }
                None => break,
            }
        }
        path
    }

    /// Take one reference on `node` (a session now shares its block).
    /// Returns `true` when this revived a *cold* node — the caller (the
    /// paged allocator) must then transfer the cache's block hold to the
    /// attaching session instead of adding a fresh refcount.
    pub fn attach(&mut self, node: usize) -> bool {
        debug_assert!(self.nodes[node].live, "attach to dead node {node}");
        let revived = self.nodes[node].cold;
        if revived {
            self.nodes[node].cold = false;
            self.cold_count -= 1;
        }
        self.nodes[node].refs += 1;
        revived
    }

    /// Insert `chunk` below `parent` pointing at `block`, registered by
    /// session `owner`, with one reference held by it; returns the node
    /// index.  If the child already exists it is attached instead and
    /// keeps its original block and owner (the caller keeps its own copy
    /// in its page table).
    pub fn insert_child(&mut self, parent: usize, chunk: &[u8], block: usize, owner: u64) -> usize {
        if let Some(&existing) = self.nodes[parent].children.get(chunk) {
            self.attach(existing);
            return existing;
        }
        let node = Node {
            children: BTreeMap::new(),
            block,
            refs: 1,
            owner,
            parent,
            key: chunk.to_vec(),
            live: true,
            cold: false,
            cooled_at: 0,
            depth: self.nodes[parent].depth + 1,
        };
        let idx = match self.free.pop() {
            Some(i) => {
                self.nodes[i] = node;
                i
            }
            None => {
                self.nodes.push(node);
                self.nodes.len() - 1
            }
        };
        self.nodes[parent].children.insert(chunk.to_vec(), idx);
        self.live_count += 1;
        idx
    }

    /// Drop one reference on `node`, removing it when the last holder
    /// leaves.  Callers release a session's path deepest-first.
    pub fn release(&mut self, node: usize) {
        debug_assert!(node != ROOT, "release of the trie root");
        debug_assert!(
            self.nodes[node].live && self.nodes[node].refs > 0,
            "release of dead/unreferenced node {node}"
        );
        self.nodes[node].refs -= 1;
        if self.nodes[node].refs == 0 {
            self.unlink(node);
        }
    }

    /// Drop one reference on `node`; when the last holder leaves, keep it
    /// resident as a *cold* cache entry instead of removing it, stamped
    /// `now` for LRU.  Returns `true` exactly when the node went cold —
    /// the caller must then transfer the departing session's block
    /// refcount to the cache (the cold hold) instead of decrementing it.
    pub fn release_to_cold(&mut self, node: usize, now: u64) -> bool {
        debug_assert!(node != ROOT, "release of the trie root");
        debug_assert!(
            self.nodes[node].live && self.nodes[node].refs > 0,
            "release of dead/unreferenced node {node}"
        );
        self.nodes[node].refs -= 1;
        if self.nodes[node].refs == 0 {
            self.nodes[node].cold = true;
            self.nodes[node].cooled_at = now;
            self.cold_count += 1;
            true
        } else {
            false
        }
    }

    /// The cold *leaf* best evicted at logical time `now`, or `None` when
    /// nothing is evictable.  Score = age since cooling divided by depth
    /// (the recompute-cost proxy): oldest-and-cheapest first, compared in
    /// exact integer cross-multiplication so ties break deterministically
    /// on the lower node index.  Only leaves qualify — see module docs.
    pub fn best_eviction(&self, now: u64) -> Option<usize> {
        let mut best: Option<(u128, usize)> = None;
        for (i, n) in self.nodes.iter().enumerate() {
            if i == ROOT || !n.live || !n.cold || !n.children.is_empty() {
                continue;
            }
            // score ~ age / depth; compare a/d > b/e as a*e > b*d.
            let age = now.saturating_sub(n.cooled_at) as u128;
            let better = match best {
                None => true,
                Some((best_score_num, best_i)) => {
                    let lhs = age * (self.nodes[best_i].depth as u128 + 1);
                    let rhs = best_score_num * (n.depth as u128 + 1);
                    lhs > rhs
                }
            };
            if better {
                best = Some((age, i));
            }
        }
        best.map(|(_, i)| i)
    }

    /// Remove a cold, unreferenced leaf chosen by
    /// [`PrefixTrie::best_eviction`]; returns its physical block so the
    /// caller can drop the cache's hold on it.
    pub fn evict(&mut self, node: usize) -> usize {
        debug_assert!(
            self.nodes[node].live && self.nodes[node].cold && self.nodes[node].refs == 0,
            "evict of non-cold node {node}"
        );
        self.nodes[node].cold = false;
        self.cold_count -= 1;
        let block = self.nodes[node].block;
        self.unlink(node);
        block
    }

    /// Unlink a refs == 0 node from its parent and recycle its slot.
    fn unlink(&mut self, node: usize) {
        debug_assert!(
            self.nodes[node].children.is_empty(),
            "removed trie node {node} still has children"
        );
        let parent = self.nodes[node].parent;
        let key = std::mem::take(&mut self.nodes[node].key);
        self.nodes[parent].children.remove(&key);
        self.nodes[node].live = false;
        self.nodes[node].children.clear();
        self.free.push(node);
        self.live_count -= 1;
    }

    /// Session whose prefill produces (or produced) `node`'s rows.
    pub fn node_owner(&self, node: usize) -> u64 {
        self.nodes[node].owner
    }

    pub fn is_cold(&self, node: usize) -> bool {
        self.nodes[node].live && self.nodes[node].cold
    }

    /// Live (non-root) nodes — the number of distinct cached chunks,
    /// including cold ones.
    pub fn len(&self) -> usize {
        self.live_count
    }

    /// Cold (resident, unreferenced, evictable) nodes.
    pub fn cold_len(&self) -> usize {
        self.cold_count
    }

    pub fn is_empty(&self) -> bool {
        self.live_count == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn chunk(tag: u8) -> Vec<u8> {
        vec![tag; BLOCK_TOKENS]
    }

    fn prompt(tags: &[u8], tail: usize) -> Vec<u8> {
        let mut p: Vec<u8> = tags.iter().flat_map(|&t| chunk(t)).collect();
        p.extend(std::iter::repeat(0xEE).take(tail));
        p
    }

    #[test]
    fn lookup_matches_longest_full_block_prefix() {
        let mut t = PrefixTrie::new();
        let a = t.insert_child(ROOT, &chunk(1), 10, 1);
        let b = t.insert_child(a, &chunk(2), 11, 1);
        assert_eq!(t.len(), 2);
        // Full match of both chunks; the 5-token tail can't match.
        assert_eq!(t.lookup(&prompt(&[1, 2], 5)), vec![(a, 10), (b, 11)]);
        // Diverging second chunk stops after the first.
        assert_eq!(t.lookup(&prompt(&[1, 3], 0)), vec![(a, 10)]);
        // A sub-block prompt never matches.
        assert_eq!(t.lookup(&[1u8; BLOCK_TOKENS - 1]), vec![]);
    }

    #[test]
    fn release_removes_only_unreferenced_nodes_deepest_first() {
        let mut t = PrefixTrie::new();
        let a = t.insert_child(ROOT, &chunk(1), 10, 1);
        let b = t.insert_child(a, &chunk(2), 11, 1);
        // A second session matches both chunks and attaches.
        t.attach(a);
        t.attach(b);
        // First session leaves: nodes survive on the second's refs.
        t.release(b);
        t.release(a);
        assert_eq!(t.len(), 2);
        assert_eq!(t.lookup(&prompt(&[1, 2], 0)).len(), 2);
        // Second session leaves: the whole path dies.
        t.release(b);
        t.release(a);
        assert!(t.is_empty());
        assert_eq!(t.lookup(&prompt(&[1, 2], 0)), vec![]);
    }

    #[test]
    fn node_slots_are_recycled() {
        let mut t = PrefixTrie::new();
        let a = t.insert_child(ROOT, &chunk(1), 10, 1);
        t.release(a);
        let b = t.insert_child(ROOT, &chunk(2), 20, 2);
        assert_eq!(a, b, "dead slot reused");
        assert_eq!(t.lookup(&prompt(&[2], 0)), vec![(b, 20)]);
    }

    #[test]
    fn release_to_cold_keeps_node_matchable_and_revivable() {
        let mut t = PrefixTrie::new();
        let a = t.insert_child(ROOT, &chunk(1), 10, 1);
        let b = t.insert_child(a, &chunk(2), 11, 1);
        assert!(t.release_to_cold(b, 5), "refs 1 -> 0: went cold");
        assert!(t.release_to_cold(a, 6));
        assert_eq!(t.len(), 2, "cold nodes stay resident");
        assert_eq!(t.cold_len(), 2);
        assert!(t.is_cold(a) && t.is_cold(b));
        // Still matchable by lookup...
        assert_eq!(t.lookup(&prompt(&[1, 2], 0)), vec![(a, 10), (b, 11)]);
        // ...and attach revives (returns true exactly for cold nodes).
        assert!(t.attach(a), "revival");
        assert!(!t.is_cold(a));
        assert_eq!(t.cold_len(), 1);
        assert!(!t.attach(a), "second attach of a hot node is plain");
    }

    #[test]
    fn release_to_cold_with_other_holders_is_a_plain_release() {
        let mut t = PrefixTrie::new();
        let a = t.insert_child(ROOT, &chunk(1), 10, 1);
        t.attach(a); // second holder
        assert!(!t.release_to_cold(a, 3), "refs 2 -> 1: not cold");
        assert!(!t.is_cold(a));
        assert_eq!(t.cold_len(), 0);
    }

    #[test]
    fn evictor_prefers_older_and_shallower_cold_leaves() {
        let mut t = PrefixTrie::new();
        // Path 1 -> 2 (depths 1, 2) and a sibling 3 (depth 1).
        let a = t.insert_child(ROOT, &chunk(1), 10, 1);
        let b = t.insert_child(a, &chunk(2), 11, 1);
        let c = t.insert_child(ROOT, &chunk(3), 12, 2);
        t.release_to_cold(b, 0); // cold leaf, depth 2, age 10 at now=10
        t.release_to_cold(a, 0); // cold but NOT a leaf (b is its child)
        t.release_to_cold(c, 8); // cold leaf, depth 1, age 2 at now=10
        // b: age/depth = 10/3; c: 2/2 -> b wins despite being deeper.
        assert_eq!(t.best_eviction(10), Some(b));
        assert_eq!(t.evict(b), 11);
        // a became a leaf: age 10/2 beats c's 2/2.
        assert_eq!(t.best_eviction(10), Some(a));
        assert_eq!(t.evict(a), 10);
        assert_eq!(t.best_eviction(10), Some(c));
        assert_eq!(t.evict(c), 12);
        assert_eq!(t.best_eviction(10), None);
        assert!(t.is_empty());
        assert_eq!(t.cold_len(), 0);
    }

    #[test]
    fn cold_mid_path_node_survives_leaf_eviction_and_revives() {
        let mut t = PrefixTrie::new();
        let a = t.insert_child(ROOT, &chunk(1), 10, 1);
        let b = t.insert_child(a, &chunk(2), 11, 1);
        t.release_to_cold(b, 1);
        t.release_to_cold(a, 1);
        t.evict(t.best_eviction(2).unwrap()); // removes b (the only leaf)
        assert_eq!(t.lookup(&prompt(&[1, 2], 0)), vec![(a, 10)], "a still matchable");
        // A new session revives a and registers a fresh deeper chunk.
        assert!(t.attach(a));
        let b2 = t.insert_child(a, &chunk(2), 20, 9);
        assert_eq!(b2, b, "slot recycled");
        assert_eq!(t.lookup(&prompt(&[1, 2], 0)), vec![(a, 10), (b2, 20)]);
    }

    #[test]
    fn duplicate_insert_attaches_existing_node() {
        let mut t = PrefixTrie::new();
        let a = t.insert_child(ROOT, &chunk(1), 10, 1);
        let same = t.insert_child(ROOT, &chunk(1), 99, 2);
        assert_eq!(a, same);
        assert_eq!(t.lookup(&prompt(&[1], 0)), vec![(a, 10)], "original block kept");
        t.release(a);
        assert_eq!(t.len(), 1, "second reference keeps the node alive");
        t.release(a);
        assert!(t.is_empty());
    }
}
