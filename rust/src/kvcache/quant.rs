//! Int4 group quantization of latent KV rows (paper Fig. 12: RAP composes
//! with Direct KV-Cache Compression).
//!
//! Symmetric per-group int4: each group of `GROUP` consecutive floats
//! shares one f32 scale; values are rounded to [-7, 7] nibbles.  Storage is
//! 0.5 byte/element + 4/GROUP bytes of scale — 5 bits/element at GROUP=32
//! (4 payload + 1 scale overhead), an ~84% cut on top of whatever width
//! reduction the pruning method already achieved.

pub const GROUP: usize = 32;
const QMAX: f32 = 7.0;

/// Quantized row: packed nibbles + per-group scales.
#[derive(Debug, Clone, PartialEq)]
pub struct QuantRow {
    pub packed: Vec<u8>,
    pub scales: Vec<f32>,
    pub len: usize,
}

impl QuantRow {
    pub fn bytes(&self) -> usize {
        self.packed.len() + 4 * self.scales.len()
    }
}

pub fn quantize(row: &[f32]) -> QuantRow {
    let n = row.len();
    let n_groups = n.div_ceil(GROUP);
    let mut scales = Vec::with_capacity(n_groups);
    let mut packed = vec![0u8; n.div_ceil(2)];
    for g in 0..n_groups {
        let lo = g * GROUP;
        let hi = (lo + GROUP).min(n);
        let amax = row[lo..hi].iter().fold(0.0f32, |a, &v| a.max(v.abs()));
        let scale = if amax > 0.0 { amax / QMAX } else { 1.0 };
        scales.push(scale);
        for i in lo..hi {
            let q = (row[i] / scale).round().clamp(-QMAX, QMAX) as i8;
            let nib = (q + 8) as u8; // bias to [1, 15]
            if i % 2 == 0 {
                packed[i / 2] |= nib;
            } else {
                packed[i / 2] |= nib << 4;
            }
        }
    }
    QuantRow {
        packed,
        scales,
        len: n,
    }
}

pub fn dequantize(q: &QuantRow, out: &mut [f32]) {
    assert_eq!(out.len(), q.len);
    for i in 0..q.len {
        let nib = if i % 2 == 0 {
            q.packed[i / 2] & 0x0F
        } else {
            q.packed[i / 2] >> 4
        };
        let v = nib as i32 - 8;
        out[i] = v as f32 * q.scales[i / GROUP];
    }
}

/// Round-trip a row through int4 (what the cache stores) — used by the
/// quantized-eval engine wrapper.
pub fn roundtrip(row: &mut [f32]) {
    let q = quantize(row);
    dequantize(&q, row);
}

/// Effective bits per element for a given row length.
pub fn bits_per_element(n: usize) -> f64 {
    let q = n.div_ceil(2) as f64 * 8.0 + n.div_ceil(GROUP) as f64 * 32.0;
    q / n as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn roundtrip_error_bounded() {
        let mut rng = Rng::new(1);
        for n in [1, 7, 32, 33, 64, 100] {
            let row: Vec<f32> = (0..n).map(|_| rng.normal_f32() * 2.0).collect();
            let q = quantize(&row);
            let mut back = vec![0.0f32; n];
            dequantize(&q, &mut back);
            for g in 0..n.div_ceil(GROUP) {
                let lo = g * GROUP;
                let hi = (lo + GROUP).min(n);
                let amax = row[lo..hi].iter().fold(0.0f32, |a, &v| a.max(v.abs()));
                let tol = amax / QMAX / 2.0 + 1e-6;
                for i in lo..hi {
                    assert!(
                        (row[i] - back[i]).abs() <= tol + 1e-5,
                        "n={n} i={i}: {} vs {}",
                        row[i],
                        back[i]
                    );
                }
            }
        }
    }

    #[test]
    fn zero_row_stays_zero() {
        let row = vec![0.0f32; 40];
        let q = quantize(&row);
        let mut back = vec![1.0f32; 40];
        dequantize(&q, &mut back);
        assert!(back.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn storage_is_about_4_bits() {
        // 4 payload bits + one f32 scale per GROUP=32 -> 5 bits/element.
        let bpe = bits_per_element(256);
        assert!(bpe >= 4.0 && bpe <= 5.01, "{bpe}");
        let row: Vec<f32> = (0..256).map(|i| i as f32).collect();
        let q = quantize(&row);
        assert_eq!(q.bytes(), 128 + 4 * 8);
    }

    #[test]
    fn extreme_values_clamp_not_overflow() {
        let row = vec![1e30f32, -1e30, 0.5, -0.5];
        let q = quantize(&row);
        let mut back = vec![0.0f32; 4];
        dequantize(&q, &mut back);
        assert!(back.iter().all(|v| v.is_finite()));
        assert!(back[0] > 0.0 && back[1] < 0.0);
    }

    #[test]
    fn preserves_sign_and_order_within_group() {
        let row = vec![-3.0f32, -1.0, 0.0, 1.0, 3.0];
        let mut back = row.clone();
        roundtrip(&mut back);
        for w in back.windows(2) {
            assert!(w[0] <= w[1] + 1e-6);
        }
    }
}
