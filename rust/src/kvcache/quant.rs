//! Int4 group quantization of latent KV rows (paper Fig. 12: RAP composes
//! with Direct KV-Cache Compression).
//!
//! Symmetric per-group int4: each group of `GROUP` consecutive floats
//! shares one f32 scale; values are rounded to [-7, 7] nibbles.  Storage is
//! 0.5 byte/element + 4/GROUP bytes of scale — 5 bits/element at GROUP=32
//! (4 payload + 1 scale overhead), an ~84% cut on top of whatever width
//! reduction the pruning method already achieved.
//!
//! # Packed block layout (`KvStorageMode::PackedInt4`)
//!
//! When the paged cache stores rows packed, each latent row of width `w`
//! occupies exactly [`row_bytes`]`(w)` bytes inside the block buffer, laid
//! out group by group:
//!
//! ```text
//! [group 0: ceil(glen/2) nibble bytes][group 0 scale: f32 LE, 4 bytes]
//! [group 1: ...                      ][group 1 scale: ...            ]
//! ```
//!
//! Element `j` of a group lives in payload byte `j / 2` — low nibble when
//! `j` is even, high nibble when odd — biased by +8 into `[1, 15]`
//! (`0 <-> -8` never occurs, so an all-zeroes buffer decodes to 0.0 rows,
//! matching the zeroed-on-allocation contract of f32 blocks).
//!
//! **Group alignment invariant:** `GROUP` is even, every group starts at a
//! byte boundary, and only the final group of a row may be shorter than
//! `GROUP`.  Rows are self-contained — no nibble or scale ever spans a row
//! boundary — so a block buffer is simply `row_bytes(w)`-strided rows and
//! the fused kernels ([`dot_rows_scaled_q4`], [`axpy_rows_q4`]) can walk
//! consecutive rows of a block without any side table.  The fused kernels
//! mirror the scalar accumulation order of `tensor::ops::{dot,
//! dot_rows_scaled, axpy_rows}` exactly, so attention over packed rows is
//! *bitwise* equal to dequantize-then-scalar-attend (propchecked in
//! `tests/kernels.rs`).

pub const GROUP: usize = 32;
const QMAX: f32 = 7.0;

/// Quantized row: packed nibbles + per-group scales.
#[derive(Debug, Clone, PartialEq)]
pub struct QuantRow {
    pub packed: Vec<u8>,
    pub scales: Vec<f32>,
    pub len: usize,
}

impl QuantRow {
    pub fn bytes(&self) -> usize {
        self.packed.len() + 4 * self.scales.len()
    }
}

/// Per-group scale for a slice of up to `GROUP` values.
#[inline]
fn group_scale(vals: &[f32]) -> f32 {
    let amax = vals.iter().fold(0.0f32, |a, &v| a.max(v.abs()));
    if amax > 0.0 {
        amax / QMAX
    } else {
        1.0
    }
}

#[inline]
fn quantize_val(v: f32, scale: f32) -> i8 {
    (v / scale).round().clamp(-QMAX, QMAX) as i8
}

pub fn quantize(row: &[f32]) -> QuantRow {
    let mut q = QuantRow {
        packed: Vec::new(),
        scales: Vec::new(),
        len: 0,
    };
    quantize_into(row, &mut q);
    q
}

/// Allocation-free `quantize` into a reusable `QuantRow` (its vectors are
/// cleared and refilled; steady-state callers reuse one scratch row).
pub fn quantize_into(row: &[f32], q: &mut QuantRow) {
    let n = row.len();
    q.len = n;
    q.scales.clear();
    q.packed.clear();
    q.packed.resize(n.div_ceil(2), 0);
    for lo in (0..n).step_by(GROUP) {
        let hi = (lo + GROUP).min(n);
        let scale = group_scale(&row[lo..hi]);
        q.scales.push(scale);
        for i in lo..hi {
            let nib = (quantize_val(row[i], scale) + 8) as u8; // bias to [1, 15]
            if i % 2 == 0 {
                q.packed[i / 2] |= nib;
            } else {
                q.packed[i / 2] |= nib << 4;
            }
        }
    }
}

pub fn dequantize(q: &QuantRow, out: &mut [f32]) {
    assert_eq!(out.len(), q.len);
    for i in 0..q.len {
        let nib = if i % 2 == 0 {
            q.packed[i / 2] & 0x0F
        } else {
            q.packed[i / 2] >> 4
        };
        let v = nib as i32 - 8;
        out[i] = v as f32 * q.scales[i / GROUP];
    }
}

/// Round-trip a row through int4 (what the cache stores) — used by the
/// quantized-eval engine wrapper and the decode post-step round-trip.
///
/// In place and allocation-free: arithmetically identical to
/// `dequantize(&quantize(row))` (pinned bitwise by a test), but without
/// the per-row heap traffic that made quantized decode allocate.
pub fn roundtrip(row: &mut [f32]) {
    let n = row.len();
    for lo in (0..n).step_by(GROUP) {
        let hi = (lo + GROUP).min(n);
        let scale = group_scale(&row[lo..hi]);
        for v in row[lo..hi].iter_mut() {
            *v = quantize_val(*v, scale) as f32 * scale;
        }
    }
}

/// Effective bits per element for a given row length.
pub fn bits_per_element(n: usize) -> f64 {
    let q = n.div_ceil(2) as f64 * 8.0 + n.div_ceil(GROUP) as f64 * 32.0;
    q / n as f64
}

/// Bytes one packed row of width `w` occupies in a block buffer (see the
/// module docs for the layout).  Equal to `quantize(row).bytes()` for any
/// row of that width.
pub fn row_bytes(w: usize) -> usize {
    let full = w / GROUP;
    let rem = w % GROUP;
    let mut bytes = full * (GROUP / 2 + 4);
    if rem > 0 {
        bytes += rem.div_ceil(2) + 4;
    }
    bytes
}

/// Quantize `src` into the packed row layout at `dst` (exactly
/// `row_bytes(src.len())` bytes).  Allocation-free; the paged cache's
/// packed write path runs this once per projected row.
pub fn quantize_row_into(src: &[f32], dst: &mut [u8]) {
    debug_assert_eq!(dst.len(), row_bytes(src.len()));
    let mut off = 0usize;
    for lo in (0..src.len()).step_by(GROUP) {
        let hi = (lo + GROUP).min(src.len());
        let glen = hi - lo;
        let payload = glen.div_ceil(2);
        let scale = group_scale(&src[lo..hi]);
        dst[off..off + payload].fill(0);
        for (j, &v) in src[lo..hi].iter().enumerate() {
            let nib = (quantize_val(v, scale) + 8) as u8;
            if j % 2 == 0 {
                dst[off + j / 2] |= nib;
            } else {
                dst[off + j / 2] |= nib << 4;
            }
        }
        dst[off + payload..off + payload + 4].copy_from_slice(&scale.to_le_bytes());
        off += payload + 4;
    }
}

/// Decode one packed row (`row_bytes(out.len())` bytes) back to f32.
/// Test/debug helper — the attention kernels below never materialize f32
/// rows.
pub fn dequantize_row(src: &[u8], out: &mut [f32]) {
    debug_assert_eq!(src.len(), row_bytes(out.len()));
    let w = out.len();
    let mut off = 0usize;
    let mut gi = 0usize;
    while gi < w {
        let glen = (w - gi).min(GROUP);
        let payload = glen.div_ceil(2);
        let scale = f32::from_le_bytes([
            src[off + payload],
            src[off + payload + 1],
            src[off + payload + 2],
            src[off + payload + 3],
        ]);
        for j in 0..glen {
            let byte = src[off + j / 2];
            let nib = if j % 2 == 0 { byte & 0x0F } else { byte >> 4 };
            out[gi] = (nib as i32 - 8) as f32 * scale;
            gi += 1;
        }
        off += payload + 4;
    }
}

/// Fused `dot_rows_scaled` over packed rows: `rows` holds
/// `out.len()` consecutive packed rows of width `w`; nibbles are expanded
/// in-register inside the dot loop, never into an f32 row buffer.
///
/// Accumulation mirrors `tensor::ops::dot` per row (4 partial sums over
/// the 4-aligned prefix, sequential tail, `acc + s0 + s1 + s2 + s3`), so
/// the result is **bitwise** equal to dequantizing each row and calling
/// `tensor::ops::dot_rows_scaled` — the packed attention path inherits the
/// scalar path's bit-identity oracle instead of an error bound.
pub fn dot_rows_scaled_q4(q: &[f32], rows: &[u8], w: usize, scale: f32, out: &mut [f32]) {
    debug_assert_eq!(q.len(), w);
    let rb = row_bytes(w);
    debug_assert_eq!(rows.len(), rb * out.len());
    let quad = (w / 4) * 4;
    for (r, o) in out.iter_mut().enumerate() {
        let row = &rows[r * rb..(r + 1) * rb];
        let mut sums = [0.0f32; 4];
        let mut acc = 0.0f32;
        let mut off = 0usize;
        let mut gi = 0usize;
        while gi < w {
            let glen = (w - gi).min(GROUP);
            let payload = glen.div_ceil(2);
            let gscale = f32::from_le_bytes([
                row[off + payload],
                row[off + payload + 1],
                row[off + payload + 2],
                row[off + payload + 3],
            ]);
            for j in 0..glen {
                let byte = row[off + j / 2];
                let nib = if j % 2 == 0 { byte & 0x0F } else { byte >> 4 };
                let v = (nib as i32 - 8) as f32 * gscale;
                let p = q[gi] * v;
                if gi < quad {
                    sums[gi % 4] += p;
                } else {
                    acc += p;
                }
                gi += 1;
            }
            off += payload + 4;
        }
        *o = (acc + sums[0] + sums[1] + sums[2] + sums[3]) * scale;
    }
}

/// Fused `axpy_rows` over packed rows: `ctx[j] += weights[r] * row_r[j]`
/// with the nibble expansion in-register.  Element-wise sequential, so
/// bitwise equal to dequantize-then-`tensor::ops::axpy_rows`.
pub fn axpy_rows_q4(weights: &[f32], rows: &[u8], w: usize, ctx: &mut [f32]) {
    let rb = row_bytes(w);
    debug_assert_eq!(rows.len(), rb * weights.len());
    debug_assert_eq!(ctx.len(), w);
    for (r, &wt) in weights.iter().enumerate() {
        let row = &rows[r * rb..(r + 1) * rb];
        let mut off = 0usize;
        let mut gi = 0usize;
        while gi < w {
            let glen = (w - gi).min(GROUP);
            let payload = glen.div_ceil(2);
            let gscale = f32::from_le_bytes([
                row[off + payload],
                row[off + payload + 1],
                row[off + payload + 2],
                row[off + payload + 3],
            ]);
            for j in 0..glen {
                let byte = row[off + j / 2];
                let nib = if j % 2 == 0 { byte & 0x0F } else { byte >> 4 };
                let v = (nib as i32 - 8) as f32 * gscale;
                ctx[gi] += wt * v;
                gi += 1;
            }
            off += payload + 4;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn roundtrip_error_bounded() {
        let mut rng = Rng::new(1);
        for n in [1, 7, 32, 33, 64, 100] {
            let row: Vec<f32> = (0..n).map(|_| rng.normal_f32() * 2.0).collect();
            let q = quantize(&row);
            let mut back = vec![0.0f32; n];
            dequantize(&q, &mut back);
            for g in 0..n.div_ceil(GROUP) {
                let lo = g * GROUP;
                let hi = (lo + GROUP).min(n);
                let amax = row[lo..hi].iter().fold(0.0f32, |a, &v| a.max(v.abs()));
                let tol = amax / QMAX / 2.0 + 1e-6;
                for i in lo..hi {
                    assert!(
                        (row[i] - back[i]).abs() <= tol + 1e-5,
                        "n={n} i={i}: {} vs {}",
                        row[i],
                        back[i]
                    );
                }
            }
        }
    }

    #[test]
    fn zero_row_stays_zero() {
        let row = vec![0.0f32; 40];
        let q = quantize(&row);
        let mut back = vec![1.0f32; 40];
        dequantize(&q, &mut back);
        assert!(back.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn storage_is_about_4_bits() {
        // 4 payload bits + one f32 scale per GROUP=32 -> 5 bits/element.
        let bpe = bits_per_element(256);
        assert!(bpe >= 4.0 && bpe <= 5.01, "{bpe}");
        let row: Vec<f32> = (0..256).map(|i| i as f32).collect();
        let q = quantize(&row);
        assert_eq!(q.bytes(), 128 + 4 * 8);
    }

    #[test]
    fn extreme_values_clamp_not_overflow() {
        let row = vec![1e30f32, -1e30, 0.5, -0.5];
        let q = quantize(&row);
        let mut back = vec![0.0f32; 4];
        dequantize(&q, &mut back);
        assert!(back.iter().all(|v| v.is_finite()));
        assert!(back[0] > 0.0 && back[1] < 0.0);
    }

    #[test]
    fn preserves_sign_and_order_within_group() {
        let row = vec![-3.0f32, -1.0, 0.0, 1.0, 3.0];
        let mut back = row.clone();
        roundtrip(&mut back);
        for w in back.windows(2) {
            assert!(w[0] <= w[1] + 1e-6);
        }
    }

    /// Random row of width `n` with occasional zeros and larger values.
    fn random_row(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n)
            .map(|i| {
                if i % 11 == 0 {
                    0.0
                } else {
                    rng.normal_f32() * 3.0
                }
            })
            .collect()
    }

    #[test]
    fn inplace_roundtrip_is_bitwise_quantize_dequantize() {
        // The allocation-free round-trip must not change quantized-decode
        // numerics: pin it bitwise to the allocating two-step version
        // across widths incl. non-GROUP multiples.
        let mut rng = Rng::new(2);
        for n in [1usize, 6, 31, 32, 33, 64, 65, 96, 100] {
            let row = random_row(&mut rng, n);
            let q = quantize(&row);
            let mut two_step = vec![0.0f32; n];
            dequantize(&q, &mut two_step);
            let mut in_place = row.clone();
            roundtrip(&mut in_place);
            for i in 0..n {
                assert_eq!(
                    in_place[i].to_bits(),
                    two_step[i].to_bits(),
                    "n={n} i={i}: {} vs {}",
                    in_place[i],
                    two_step[i]
                );
            }
        }
    }

    #[test]
    fn quantize_into_reuses_and_matches() {
        let mut rng = Rng::new(3);
        let mut scratch = QuantRow {
            packed: Vec::new(),
            scales: Vec::new(),
            len: 0,
        };
        for n in [40usize, 6, 33, 64] {
            let row = random_row(&mut rng, n);
            quantize_into(&row, &mut scratch);
            assert_eq!(scratch, quantize(&row), "n={n}");
        }
    }

    #[test]
    fn width_not_multiple_of_group_round_trips() {
        // Odd tail group, incl. odd glen (trailing half-filled byte).
        let mut rng = Rng::new(4);
        for n in [1usize, 5, 31, 33, 45, 63, 95] {
            let row = random_row(&mut rng, n);
            let mut packed = vec![0u8; row_bytes(n)];
            quantize_row_into(&row, &mut packed);
            let mut back = vec![0.0f32; n];
            dequantize_row(&packed, &mut back);
            let mut expect = row.clone();
            roundtrip(&mut expect);
            for i in 0..n {
                assert_eq!(back[i].to_bits(), expect[i].to_bits(), "n={n} i={i}");
            }
        }
    }

    #[test]
    fn all_zero_group_amid_nonzero_groups() {
        // Group 1 of 3 is all zeros: its scale must be the 1.0 sentinel
        // (not 0.0/QMAX), it must decode to exact zeros, and its
        // neighbours must be unaffected.
        let mut rng = Rng::new(5);
        let mut row = random_row(&mut rng, 3 * GROUP);
        row[GROUP..2 * GROUP].fill(0.0);
        let q = quantize(&row);
        assert_eq!(q.scales[1], 1.0);
        let mut back = vec![1.0f32; row.len()];
        dequantize(&q, &mut back);
        assert!(back[GROUP..2 * GROUP].iter().all(|&v| v == 0.0));
        assert!(back[..GROUP].iter().any(|&v| v != 0.0));
        // Packed layout agrees.
        let mut packed = vec![0u8; row_bytes(row.len())];
        quantize_row_into(&row, &mut packed);
        let mut back2 = vec![1.0f32; row.len()];
        dequantize_row(&packed, &mut back2);
        assert_eq!(back, back2);
    }

    #[test]
    fn bits_per_element_matches_actual_bytes() {
        // Propcheck: the documented bits/element figure must be exactly
        // what a QuantRow (and the packed row layout) occupy, and stay at
        // or under the documented 5-bit bound for GROUP-aligned widths.
        let mut rng = Rng::new(6);
        for n in [1usize, 2, 7, 31, 32, 33, 64, 96, 100, 256, 257] {
            let row = random_row(&mut rng, n);
            let q = quantize(&row);
            let actual_bits = q.bytes() as f64 * 8.0;
            assert!(
                (bits_per_element(n) * n as f64 - actual_bits).abs() < 1e-9,
                "n={n}: bpe says {} bits, QuantRow holds {actual_bits}",
                bits_per_element(n) * n as f64
            );
            assert_eq!(q.bytes(), row_bytes(n), "packed layout size n={n}");
            if n % GROUP == 0 {
                assert!(bits_per_element(n) <= 5.0 + 1e-9, "n={n}");
            }
        }
    }

    #[test]
    fn fused_q4_kernels_match_dequantized_scalar_bitwise() {
        use crate::tensor::ops;
        let mut rng = Rng::new(7);
        for (n_rows, w) in [(1usize, 6usize), (3, 8), (5, 32), (4, 33), (2, 64), (3, 95)] {
            let rb = row_bytes(w);
            let mut rows = vec![0u8; n_rows * rb];
            let mut deq = vec![0.0f32; n_rows * w];
            for r in 0..n_rows {
                let row = random_row(&mut rng, w);
                quantize_row_into(&row, &mut rows[r * rb..(r + 1) * rb]);
                dequantize_row(&rows[r * rb..(r + 1) * rb], &mut deq[r * w..(r + 1) * w]);
            }
            let q: Vec<f32> = (0..w).map(|_| rng.normal_f32()).collect();
            let weights: Vec<f32> = (0..n_rows).map(|_| rng.normal_f32()).collect();
            let scale = 0.173f32;

            let mut fused = vec![0.0f32; n_rows];
            dot_rows_scaled_q4(&q, &rows, w, scale, &mut fused);
            let mut reference = vec![0.0f32; n_rows];
            ops::dot_rows_scaled(&q, &deq, w, scale, &mut reference);
            for r in 0..n_rows {
                assert_eq!(
                    fused[r].to_bits(),
                    reference[r].to_bits(),
                    "dot rows={n_rows} w={w} r={r}: {} vs {}",
                    fused[r],
                    reference[r]
                );
            }

            let mut ctx_fused: Vec<f32> = (0..w).map(|_| rng.normal_f32()).collect();
            let mut ctx_ref = ctx_fused.clone();
            axpy_rows_q4(&weights, &rows, w, &mut ctx_fused);
            ops::axpy_rows(&weights, &deq, w, &mut ctx_ref);
            for j in 0..w {
                assert_eq!(
                    ctx_fused[j].to_bits(),
                    ctx_ref[j].to_bits(),
                    "axpy rows={n_rows} w={w} j={j}"
                );
            }
        }
    }

    #[test]
    fn zeroed_packed_buffer_decodes_to_zero_rows() {
        // Blocks are zeroed on allocation; a never-written packed row must
        // read as a zero row (scale 0.0, nibbles biased at 0 -> -8 * 0.0).
        let w = 45;
        let packed = vec![0u8; row_bytes(w)];
        let mut out = vec![1.0f32; w];
        dequantize_row(&packed, &mut out);
        assert!(out.iter().all(|&v| v == 0.0));
    }
}
