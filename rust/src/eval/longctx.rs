//! Long-context suite — the LongBench analog (Figs. 9/10).
//!
//! Eight synthetic tasks, each constructed so success requires *using the
//! KV cache across a long span* (the capability LongBench measures and the
//! one most sensitive to KV compression):
//!
//!   NEEDLE   recall a planted key-value fact from early context
//!   PREFIX   copy a sentence seen at the start of the context
//!   PATTERN  continue a periodic token pattern spanning the context
//!   ENTITY   complete the paragraph's entity name (natural corpus text)
//!   REPEAT   verbatim continuation of a repeated paragraph
//!   TAIL_LM  plain LM accuracy at the far end of a long context
//!   KVDIST   recall the value bound to the *first* of many keys
//!   ALternating copy (ALT): continue an a/b alternation with distractors

use anyhow::Result;

use crate::model::{argmax, Engine};
use crate::util::rng::Rng;

#[derive(Debug, Clone)]
pub struct LongCtxScore {
    pub task: &'static str,
    pub correct: usize,
    pub total: usize,
}

impl LongCtxScore {
    pub fn accuracy(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.correct as f64 / self.total as f64
        }
    }
}

pub const TASKS: [&str; 8] = [
    "NEEDLE", "PREFIX", "PATTERN", "ENTITY", "REPEAT", "TAIL_LM", "KVDIST", "ALT",
];

/// Score teacher-forced accuracy of `engine` on `target` given `prompt`.
fn score_continuation(engine: &Engine, prompt: &[u8], target: &[u8], s_max: usize) -> usize {
    let mut cache = engine.new_cache(s_max.max(prompt.len() + target.len() + 1));
    let mut logits = Vec::new();
    for (i, &t) in prompt.iter().enumerate() {
        logits = engine.step(t, i, &mut cache);
    }
    let mut correct = 0;
    let mut pos = prompt.len();
    for &want in target {
        if argmax(&logits) as u8 == want {
            correct += 1;
        }
        logits = engine.step(want, pos, &mut cache);
        pos += 1;
    }
    correct
}

/// Build + run the eight tasks at context length `ctx_len`.
/// `corpus` supplies natural text for the corpus-based tasks.
pub fn longctx_suite(
    engine: &Engine,
    corpus: &[u8],
    ctx_len: usize,
    cases_per_task: usize,
    seed: u64,
) -> Result<Vec<LongCtxScore>> {
    let mut rng = Rng::new(seed);
    let mut scores: Vec<LongCtxScore> = TASKS
        .iter()
        .map(|t| LongCtxScore {
            task: t,
            correct: 0,
            total: 0,
        })
        .collect();
    let s_max = ctx_len + 40;

    for _ in 0..cases_per_task {
        // -- NEEDLE: "key is X" early, filler, then query "key is".
        {
            let key = b"zq";
            let val = (b'a' + rng.below(26) as u8) as u8;
            let mut prompt = Vec::new();
            prompt.extend_from_slice(b"the ");
            prompt.extend_from_slice(key);
            prompt.extend_from_slice(b" is ");
            prompt.push(val);
            prompt.extend_from_slice(b". ");
            let fill_start = rng.below(corpus.len() - ctx_len - 1);
            while prompt.len() < ctx_len - 10 {
                prompt.push(corpus[fill_start + prompt.len() % (ctx_len / 2)]);
            }
            prompt.extend_from_slice(b" the ");
            prompt.extend_from_slice(key);
            prompt.extend_from_slice(b" is ");
            let c = score_continuation(engine, &prompt, &[val], s_max);
            scores[0].correct += c;
            scores[0].total += 1;
        }
        // -- PREFIX: first 16 bytes repeated verbatim at the end.
        {
            let start = rng.below(corpus.len() - ctx_len - 40);
            let sent = &corpus[start..start + 16];
            let mut prompt = sent.to_vec();
            prompt.extend_from_slice(&corpus[start + 16..start + ctx_len - 20]);
            prompt.extend_from_slice(sent);
            // model should continue the *original* continuation
            let target = &corpus[start + 16..start + 16 + 8];
            let c = score_continuation(engine, &prompt, target, s_max);
            scores[1].correct += c;
            scores[1].total += target.len();
        }
        // -- PATTERN: periodic word pattern filling the context.
        {
            let words: [&[u8]; 3] = [b"lun ", b"vex ", b"pom "];
            let mut prompt = Vec::new();
            while prompt.len() < ctx_len - 8 {
                prompt.extend_from_slice(words[(prompt.len() / 4) % 3]);
            }
            // truncate to a whole number of words so the target aligns
            let whole = (prompt.len() / 4) * 4;
            prompt.truncate(whole);
            let target = words[(whole / 4) % 3];
            let c = score_continuation(engine, &prompt, target, s_max);
            scores[2].correct += c;
            scores[2].total += target.len();
        }
        // -- ENTITY: natural corpus window, predict entity completion.
        {
            let start = rng.below(corpus.len() - ctx_len - 1);
            let window = &corpus[start..start + ctx_len];
            // find a capitalised entity occurring at least twice
            if let Some((pos, len)) = second_entity(window) {
                let prompt = &window[..pos + 1]; // first byte of 2nd occurrence
                let target = &window[pos + 1..(pos + len).min(window.len())];
                if !target.is_empty() {
                    let c = score_continuation(engine, prompt, target, s_max);
                    scores[3].correct += c;
                    scores[3].total += target.len();
                }
            }
        }
        // -- REPEAT: a paragraph shown twice; third showing must continue.
        {
            let start = rng.below(corpus.len() - ctx_len);
            let para_len = (ctx_len / 2).saturating_sub(4).max(16);
            let para = &corpus[start..start + para_len];
            let mut prompt = para.to_vec();
            prompt.extend_from_slice(b". ");
            prompt.extend_from_slice(&para[..para_len / 2]);
            let target = &para[para_len / 2..para_len / 2 + 8];
            let c = score_continuation(engine, &prompt, target, s_max);
            scores[4].correct += c;
            scores[4].total += target.len();
        }
        // -- TAIL_LM: plain teacher-forced accuracy at the context tail.
        {
            let start = rng.below(corpus.len() - ctx_len - 16);
            let prompt = &corpus[start..start + ctx_len];
            let target = &corpus[start + ctx_len..start + ctx_len + 12];
            let c = score_continuation(engine, prompt, target, s_max);
            scores[5].correct += c;
            scores[5].total += target.len();
        }
        // -- KVDIST: many key-value pairs; query the FIRST one.
        {
            let n_pairs = (ctx_len / 16).max(3).min(26);
            let mut prompt = Vec::new();
            let vals: Vec<u8> = (0..n_pairs)
                .map(|_| b'a' + rng.below(26) as u8)
                .collect();
            for (i, &v) in vals.iter().enumerate() {
                prompt.extend_from_slice(b"k");
                prompt.push(b'a' + (i % 26) as u8);
                prompt.extend_from_slice(b" is ");
                prompt.push(v);
                prompt.extend_from_slice(b". ");
            }
            prompt.extend_from_slice(b"ka is ");
            let c = score_continuation(engine, &prompt, &[vals[0]], s_max.max(prompt.len() + 4));
            scores[6].correct += c;
            scores[6].total += 1;
        }
        // -- ALT: strict alternation with a distractor block in between.
        {
            let mut prompt = Vec::new();
            while prompt.len() < ctx_len / 2 {
                prompt.extend_from_slice(b"xy ");
            }
            let start = rng.below(corpus.len() - ctx_len);
            prompt.extend_from_slice(&corpus[start..start + ctx_len / 4]);
            prompt.extend_from_slice(b" xy xy x");
            let target = b"y xy";
            let c = score_continuation(engine, &prompt, target, s_max);
            scores[7].correct += c;
            scores[7].total += target.len();
        }
    }
    Ok(scores)
}

/// Find the second occurrence of a capitalised entity: returns (position of
/// its first byte, entity length).
fn second_entity(window: &[u8]) -> Option<(usize, usize)> {
    for i in 1..window.len() {
        if window[i].is_ascii_uppercase() {
            let mut end = i + 1;
            while end < window.len() && window[end].is_ascii_lowercase() {
                end += 1;
            }
            let ent = &window[i..end];
            if ent.len() >= 4 && ent.len() <= 12 {
                // appeared before?
                if window[..i]
                    .windows(ent.len())
                    .any(|w| w == ent)
                {
                    return Some((i, ent.len()));
                }
            }
        }
    }
    None
}

pub fn average_accuracy(scores: &[LongCtxScore]) -> f64 {
    let with_data: Vec<&LongCtxScore> = scores.iter().filter(|s| s.total > 0).collect();
    if with_data.is_empty() {
        return 0.0;
    }
    with_data.iter().map(|s| s.accuracy()).sum::<f64>() / with_data.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn second_entity_detection() {
        let w = b"we saw Kavu at noon. later Kavu slept deeply";
        let (pos, len) = second_entity(w).unwrap();
        assert_eq!(&w[pos..pos + len], b"Kavu");
        assert!(pos > 10);
    }

    #[test]
    fn second_entity_none_when_unique() {
        assert!(second_entity(b"only Kavu once here").is_none());
    }
}
