//! Perplexity over the held-out corpus tail — the WikiText-2-PPL analog
//! (Tables 4/5/7/13/14, Figs. 13–15).  Matches the python pipeline's
//! windowing exactly so `ppl_python` in the manifest is directly
//! comparable (cross-checked in integration tests).

use anyhow::Result;

use crate::model::Engine;

/// Contiguous non-overlapping (input, target) windows, python
/// `data.eval_windows` semantics.
pub fn eval_windows(data: &[u8], seq: usize, max_windows: usize) -> Vec<(Vec<u8>, Vec<u8>)> {
    let n = ((data.len().saturating_sub(1)) / seq).min(max_windows);
    (0..n)
        .map(|i| {
            (
                data[i * seq..i * seq + seq].to_vec(),
                data[i * seq + 1..i * seq + seq + 1].to_vec(),
            )
        })
        .collect()
}

/// exp(mean NLL) over windows.
pub fn eval_ppl(engine: &Engine, data: &[u8], seq: usize, max_windows: usize) -> Result<f64> {
    let windows = eval_windows(data, seq, max_windows);
    anyhow::ensure!(!windows.is_empty(), "eval corpus too small for seq {seq}");
    let mut total = 0.0f64;
    let mut count = 0usize;
    for (x, y) in &windows {
        total += engine.nll(x, y, seq) * x.len() as f64;
        count += x.len();
    }
    Ok((total / count as f64).exp())
}

/// PPL with the KV cache round-tripped through int4 after every write
/// (Fig. 12: RAP + 4-bit KV-cache quantization).
pub fn eval_ppl_quantized(
    engine: &Engine,
    data: &[u8],
    seq: usize,
    max_windows: usize,
) -> Result<f64> {
    let windows = eval_windows(data, seq, max_windows);
    anyhow::ensure!(!windows.is_empty(), "eval corpus too small");
    let mut total = 0.0f64;
    let mut count = 0usize;
    for (x, y) in &windows {
        let mut cache = engine.new_cache(seq);
        for (i, (&t, &tgt)) in x.iter().zip(y.iter()).enumerate() {
            let logits = engine.step(t, i, &mut cache);
            // Quantize the rows just written, as the cache store would.
            for lc in &mut cache.layers {
                for h in 0..lc.n_kv_heads {
                    crate::kvcache::quant::roundtrip(lc.k_row_mut(h, i));
                    crate::kvcache::quant::roundtrip(lc.v_row_mut(h, i));
                }
            }
            let max = logits.iter().copied().fold(f32::NEG_INFINITY, f32::max);
            let lse: f32 =
                logits.iter().map(|&v| (v - max).exp()).sum::<f32>().ln() + max;
            total += (lse - logits[tgt as usize]) as f64;
            count += 1;
        }
    }
    Ok((total / count as f64).exp())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn windows_match_python_semantics() {
        let data: Vec<u8> = (0..200u8).collect();
        let w = eval_windows(&data, 64, 8);
        assert_eq!(w.len(), 3); // (200-1)/64 = 3
        assert_eq!(w[0].0[0], 0);
        assert_eq!(w[0].1[0], 1); // shifted by one
        assert_eq!(w[1].0[0], 64);
        assert_eq!(w[1].0.len(), 64);
    }

    #[test]
    fn windows_capped() {
        let data: Vec<u8> = vec![0; 1000];
        assert_eq!(eval_windows(&data, 10, 4).len(), 4);
    }
}
