//! Probe-task suite — the lm-eval-harness analog (Tables 4/9/13/14, Fig. 8).
//!
//! Six synthetic tasks measure *graded capability categories* of the tiny
//! byte-level models, mirroring the role the paper's six commonsense tasks
//! play: each task selects next-byte prediction sites of a distinct kind
//! from the held-out corpus and scores top-1 accuracy there.
//!
//!   BI  bigram        — any mid-word position (local statistics)
//!   FW  frequent-word — first byte after a space following a frequent word
//!   RW  rare-word     — continuation inside rare (long) words
//!   LR  long-range    — second occurrence of a capitalised entity
//!   SB  boundary      — the space after a sentence-ending ". "
//!   PU  punctuation   — predicting '.'/'?'/' ' at clause ends

use anyhow::Result;

use crate::model::{argmax, Engine};

#[derive(Debug, Clone)]
pub struct ProbeScore {
    pub task: &'static str,
    pub correct: usize,
    pub total: usize,
}

impl ProbeScore {
    pub fn accuracy(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.correct as f64 / self.total as f64
        }
    }
}

pub const TASKS: [&str; 6] = ["BI", "FW", "RW", "LR", "SB", "PU"];

/// Find prediction sites for each task in a context window.
/// Returns (task_index, target_position) pairs; the model must predict
/// byte at `target_position` given the prefix.
fn find_sites(ctx: &[u8]) -> Vec<(usize, usize)> {
    let mut sites = Vec::new();
    let is_alpha = |b: u8| b.is_ascii_lowercase();
    for i in 8..ctx.len() {
        let prev = ctx[i - 1];
        let cur = ctx[i];
        // BI: inside a word (prev and cur lowercase).
        if is_alpha(prev) && is_alpha(cur) && i % 7 == 0 {
            sites.push((0, i));
        }
        // FW: first letter of a word following a short (frequent) word.
        if prev == b' ' && is_alpha(cur) {
            let wstart = ctx[..i - 1]
                .iter()
                .rposition(|&b| !b.is_ascii_lowercase())
                .map(|p| p + 1)
                .unwrap_or(0);
            let wlen = (i - 1).saturating_sub(wstart);
            if (2..=3).contains(&wlen) && i % 3 == 0 {
                sites.push((1, i));
            } else if wlen >= 7 && is_alpha(cur) {
                // RW handled below via word length
            }
        }
        // RW: 4th+ byte of a long word (rare words are long under our
        // generator's Zipf construction).
        if is_alpha(cur) && i >= 4 && ctx[i - 4..i].iter().all(|&b| is_alpha(b)) && i % 5 == 0 {
            sites.push((2, i));
        }
        // LR: entity recall — capitalised token seen before in the window.
        if cur.is_ascii_uppercase() {
            // find end of entity
            let mut end = i + 1;
            while end < ctx.len() && ctx[end].is_ascii_lowercase() {
                end += 1;
            }
            let ent = &ctx[i..end];
            if ent.len() >= 4 {
                if let Some(_first) = find_sub(&ctx[..i.saturating_sub(1)], ent) {
                    // predict the entity's 2nd byte given its 1st (the
                    // model must recall which entity this paragraph uses)
                    if i + 1 < ctx.len() {
                        sites.push((3, i + 1));
                    }
                }
            }
        }
        // SB: after ". " predict next sentence start.
        if i >= 2 && ctx[i - 2] == b'.' && prev == b' ' {
            sites.push((4, i));
        }
        // PU: predict punctuation/space itself.
        if (cur == b'.' || cur == b'?' || cur == b' ') && is_alpha(prev) && i % 4 == 0 {
            sites.push((5, i));
        }
    }
    sites
}

fn find_sub(hay: &[u8], needle: &[u8]) -> Option<usize> {
    if needle.is_empty() || hay.len() < needle.len() {
        return None;
    }
    hay.windows(needle.len()).position(|w| w == needle)
}

/// Run the probe suite: slide windows over the eval corpus, score each
/// task's sites by teacher-forced top-1 accuracy.
pub fn probe_suite(
    engine: &Engine,
    data: &[u8],
    window: usize,
    max_windows: usize,
    max_sites_per_task: usize,
) -> Result<Vec<ProbeScore>> {
    let mut scores: Vec<ProbeScore> = TASKS
        .iter()
        .map(|t| ProbeScore {
            task: t,
            correct: 0,
            total: 0,
        })
        .collect();
    let n_windows = ((data.len() - 1) / window).min(max_windows);
    for w in 0..n_windows {
        let ctx = &data[w * window..(w + 1) * window];
        let sites = find_sites(ctx);
        if sites.is_empty() {
            continue;
        }
        // One forward pass per window: predictions at every position.
        let mut cache = engine.new_cache(window);
        let mut preds = vec![0u8; ctx.len()];
        for (i, &t) in ctx[..ctx.len() - 1].iter().enumerate() {
            let logits = engine.step(t, i, &mut cache);
            preds[i + 1] = argmax(&logits) as u8;
        }
        for (task, pos) in sites {
            if scores[task].total >= max_sites_per_task {
                continue;
            }
            scores[task].total += 1;
            if preds[pos] == ctx[pos] {
                scores[task].correct += 1;
            }
        }
    }
    Ok(scores)
}

pub fn average_accuracy(scores: &[ProbeScore]) -> f64 {
    let with_data: Vec<&ProbeScore> = scores.iter().filter(|s| s.total > 0).collect();
    if with_data.is_empty() {
        return 0.0;
    }
    with_data.iter().map(|s| s.accuracy()).sum::<f64>() / with_data.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sites_found_in_structured_text() {
        let text = b"the quick wombat runs. Kavu said so. the small Kavu ran again? yes the end of it all. more words here";
        let sites = find_sites(text);
        assert!(!sites.is_empty());
        // At least a boundary site (after ". ") exists.
        assert!(sites.iter().any(|&(t, _)| t == 4));
        // All positions are in range.
        assert!(sites.iter().all(|&(_, p)| p < text.len()));
    }

    #[test]
    fn find_sub_works() {
        assert_eq!(find_sub(b"hello world", b"world"), Some(6));
        assert_eq!(find_sub(b"hello", b"xyz"), None);
        assert_eq!(find_sub(b"ab", b"abc"), None);
    }

    #[test]
    fn accuracy_math() {
        let s = ProbeScore {
            task: "BI",
            correct: 3,
            total: 4,
        };
        assert!((s.accuracy() - 0.75).abs() < 1e-12);
    }
}
