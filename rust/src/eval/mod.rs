//! Evaluation harnesses: perplexity, probe tasks (lm-eval analog),
//! long-context suite (LongBench analog), and the int4-quantized variant
//! of each (Fig. 12).

pub mod longctx;
pub mod ppl;
pub mod tasks;

pub use longctx::{longctx_suite, LongCtxScore};
pub use ppl::{eval_ppl, eval_ppl_quantized};
pub use tasks::{probe_suite, ProbeScore};
