//! `artifacts/manifest.json` loader: model configs, variant registry,
//! weight-tensor index, HLO graph signatures, and the rope-bench catalog.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::config::{ModelConfig, VariantSpec};
use crate::util::json::{self, Value};

#[derive(Debug, Clone)]
pub struct TensorEntry {
    pub name: String,
    pub shape: Vec<usize>,
    /// Byte offset into the variant's weights .bin file.
    pub offset: usize,
}

#[derive(Debug, Clone)]
pub struct VariantEntry {
    pub spec: VariantSpec,
    pub weights_path: String,
    pub weights_bytes: usize,
    pub tensors: Vec<TensorEntry>,
    /// WikiText-analog PPL measured by the python pipeline (cross-checked
    /// against the Rust engine in integration tests).
    pub ppl_python: f64,
}

#[derive(Debug, Clone)]
pub struct HloGraph {
    pub kind: String,
    pub path: String,
    pub batch: usize,
    pub seq: usize,
    pub s_max: usize,
    pub n_weights: usize,
    pub weight_names: Vec<String>,
    pub k_rank: Vec<usize>,
    pub v_rank: Vec<usize>,
}

#[derive(Debug, Clone)]
pub struct ModelEntry {
    pub config: ModelConfig,
    pub variants: BTreeMap<String, VariantEntry>,
    /// variant key -> graph name ("prefill128", "decode_b1", ...) -> graph.
    pub hlo: BTreeMap<String, BTreeMap<String, HloGraph>>,
}

#[derive(Debug, Clone)]
pub struct RopeBenchEntry {
    pub impl_name: String,
    pub batch: usize,
    pub seq: usize,
    pub ratio: f64,
    pub m: usize,
    pub path: String,
}

#[derive(Debug)]
pub struct Manifest {
    pub root: PathBuf,
    pub corpus_path: PathBuf,
    pub s_max: usize,
    pub eval_seq: usize,
    pub eval_windows: usize,
    pub models: BTreeMap<String, ModelEntry>,
    pub rope_bench: Vec<RopeBenchEntry>,
}

impl Manifest {
    /// Locate artifacts/ relative to the current dir or the repo root.
    pub fn locate() -> Result<PathBuf> {
        for cand in ["artifacts", "../artifacts", "../../artifacts"] {
            let p = PathBuf::from(cand);
            if p.join("manifest.json").exists() {
                return Ok(p);
            }
        }
        bail!("artifacts/manifest.json not found — run `make artifacts` first")
    }

    pub fn load_default() -> Result<Manifest> {
        Self::load(&Self::locate()?)
    }

    pub fn load(root: &Path) -> Result<Manifest> {
        let text = std::fs::read_to_string(root.join("manifest.json"))
            .with_context(|| format!("reading {}/manifest.json", root.display()))?;
        let v = json::parse(&text).map_err(|e| anyhow::anyhow!("manifest parse: {e}"))?;

        let mut models = BTreeMap::new();
        for (name, entry) in v.req("models").as_obj().unwrap() {
            let config = ModelConfig::from_json(entry.req("config"));
            let mut variants = BTreeMap::new();
            for (key, ve) in entry.req("variants").as_obj().unwrap() {
                let w = ve.req("weights");
                let tensors = w
                    .req("tensors")
                    .as_arr()
                    .unwrap()
                    .iter()
                    .map(|t| TensorEntry {
                        name: t.req("name").as_str().unwrap().to_string(),
                        shape: t.req("shape").usize_arr(),
                        offset: t.req("offset").as_usize().unwrap(),
                    })
                    .collect();
                variants.insert(
                    key.clone(),
                    VariantEntry {
                        spec: VariantSpec::from_json(ve.req("spec")),
                        weights_path: w.req("path").as_str().unwrap().to_string(),
                        weights_bytes: w.req("bytes").as_usize().unwrap(),
                        tensors,
                        ppl_python: ve.req("ppl_python").as_f64().unwrap(),
                    },
                );
            }
            let mut hlo = BTreeMap::new();
            if let Some(hmodels) = v.get("hlo").and_then(|h| h.get(name)) {
                for (key, graphs) in hmodels.as_obj().unwrap() {
                    let mut gm = BTreeMap::new();
                    for (gname, g) in graphs.as_obj().unwrap() {
                        gm.insert(gname.clone(), parse_graph(g));
                    }
                    hlo.insert(key.clone(), gm);
                }
            }
            models.insert(
                name.clone(),
                ModelEntry {
                    config,
                    variants,
                    hlo,
                },
            );
        }

        let rope_bench = v
            .get("rope_bench")
            .and_then(|r| r.as_arr())
            .map(|arr| {
                arr.iter()
                    .map(|e| RopeBenchEntry {
                        impl_name: e.req("impl").as_str().unwrap().to_string(),
                        batch: e.req("batch").as_usize().unwrap(),
                        seq: e.req("seq").as_usize().unwrap(),
                        ratio: e.req("ratio").as_f64().unwrap(),
                        m: e.req("m").as_usize().unwrap(),
                        path: e.req("path").as_str().unwrap().to_string(),
                    })
                    .collect()
            })
            .unwrap_or_default();

        Ok(Manifest {
            root: root.to_path_buf(),
            corpus_path: root.join(v.req("corpus").as_str().unwrap()),
            s_max: v.req("s_max").as_usize().unwrap(),
            eval_seq: v.req("eval").req("seq").as_usize().unwrap(),
            eval_windows: v.req("eval").req("windows").as_usize().unwrap(),
            models,
            rope_bench,
        })
    }

    pub fn model(&self, name: &str) -> Result<&ModelEntry> {
        self.models
            .get(name)
            .with_context(|| format!("model {name:?} not in manifest"))
    }

    pub fn corpus(&self) -> Result<Vec<u8>> {
        Ok(std::fs::read(&self.corpus_path)?)
    }

    /// Eval split (tail 10%) of the corpus, matching python's
    /// `train_eval_split`.
    pub fn eval_corpus(&self) -> Result<Vec<u8>> {
        let c = self.corpus()?;
        let cut = (c.len() as f64 * 0.9) as usize;
        Ok(c[cut..].to_vec())
    }
}

fn parse_graph(g: &Value) -> HloGraph {
    HloGraph {
        kind: g.req("kind").as_str().unwrap().to_string(),
        path: g.req("path").as_str().unwrap().to_string(),
        batch: g.req("batch").as_usize().unwrap(),
        seq: g.get("seq").and_then(|s| s.as_usize()).unwrap_or(1),
        s_max: g.req("s_max").as_usize().unwrap(),
        n_weights: g.req("n_weights").as_usize().unwrap(),
        weight_names: g
            .req("weight_names")
            .as_arr()
            .unwrap()
            .iter()
            .map(|n| n.as_str().unwrap().to_string())
            .collect(),
        k_rank: g.req("k_rank").usize_arr(),
        v_rank: g.req("v_rank").usize_arr(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Integration-level manifest tests live in rust/tests; here we check
    /// the graph parser on a synthetic value.
    #[test]
    fn parse_graph_entry() {
        let g = json::parse(
            r#"{"kind":"decode","path":"hlo/x.hlo.txt","batch":2,"s_max":384,
                "n_weights":3,"weight_names":["a","b","c"],
                "k_rank":[8],"v_rank":[10]}"#,
        )
        .unwrap();
        let hg = parse_graph(&g);
        assert_eq!(hg.kind, "decode");
        assert_eq!(hg.batch, 2);
        assert_eq!(hg.weight_names.len(), 3);
        assert_eq!(hg.seq, 1);
    }
}
