//! Accuracy experiments: Fig. 4 (layer sensitivity), the Table 4/9/13/14 +
//! Fig. 8 accuracy sweep, the LongBench analog (Figs. 9/10), and the 4-bit
//! quantization compatibility check (Fig. 12).

use anyhow::Result;

use crate::eval::{
    eval_ppl, eval_ppl_quantized, longctx_suite, probe_suite,
};
use crate::eval::longctx;
use crate::eval::tasks;
use crate::experiments::{print_table, ExpContext};
use crate::model::load_engine;
use crate::util::json::{arr, num, obj, s};

const RATIO_KEYS: [(&str, f64); 5] = [
    ("r10", 0.10),
    ("r20", 0.20),
    ("r30", 0.30),
    ("r40", 0.40),
    ("r50", 0.50),
];

/// Fig. 4: PPL after pruning one layer at a time at rho=30%.
pub fn fig4_layer_sensitivity(ctx: &ExpContext) -> Result<()> {
    let name = "tinyllama";
    let entry = ctx.manifest.model(name)?;
    let corpus = ctx.manifest.eval_corpus()?;
    let windows = if ctx.quick { 4 } else { 12 };
    let base = load_engine(&ctx.manifest, name, "baseline_r00")?;
    let base_ppl = eval_ppl(&base, &corpus, ctx.manifest.eval_seq, windows)?;
    println!("\nFig. 4 ({name}): PPL pruning one layer at a time (rho=30%), baseline {base_ppl:.3}");
    let mut rows = Vec::new();
    let mut json_rows = Vec::new();
    for l in 0..entry.config.n_layers {
        let key = format!("rap_r30_layer{l}");
        if !entry.variants.contains_key(&key) {
            continue;
        }
        let engine = load_engine(&ctx.manifest, name, &key)?;
        let ppl = eval_ppl(&engine, &corpus, ctx.manifest.eval_seq, windows)?;
        rows.push(vec![format!("layer {l}"), format!("{ppl:.3}"), format!("+{:.1}%", 100.0 * (ppl / base_ppl - 1.0))]);
        json_rows.push(obj(vec![("layer", num(l as f64)), ("ppl", num(ppl))]));
    }
    print_table(&["pruned layer", "PPL", "vs baseline"], &rows);
    println!("(paper: front/back layers hurt most, middle least)");
    ctx.write_json(
        "fig4",
        &obj(vec![("baseline_ppl", num(base_ppl)), ("layers", arr(json_rows))]),
    )
}

/// Tables 4/9/13/14 + Figs. 8/20: PPL and probe-task accuracy across rho.
pub fn accuracy_sweep(ctx: &ExpContext) -> Result<()> {
    let corpus = ctx.manifest.eval_corpus()?;
    let windows = if ctx.quick { 3 } else { 10 };
    let probe_windows = if ctx.quick { 4 } else { 16 };
    let mut json_models = Vec::new();
    for (name, entry) in &ctx.manifest.models {
        println!("\nAccuracy sweep ({name}): PPL (avg probe accuracy), cf. paper Table 4:");
        let base = load_engine(&ctx.manifest, name, "baseline_r00")?;
        let base_ppl = eval_ppl(&base, &corpus, ctx.manifest.eval_seq, windows)?;
        let base_probe = probe_suite(&base, &corpus, ctx.manifest.eval_seq, probe_windows, 64)?;
        let base_acc = tasks::average_accuracy(&base_probe);
        let mut rows = Vec::new();
        let mut json_rows = Vec::new();
        for (tag, rho) in RATIO_KEYS {
            let mut row = vec![format!("{:.0}%", rho * 100.0)];
            row.push(format!("{base_ppl:.2} ({base_acc:.2})"));
            for m in ["svd", "palu", "rap"] {
                let key = format!("{m}_{tag}");
                let Some(_) = entry.variants.get(&key) else {
                    row.push("-".into());
                    continue;
                };
                let engine = load_engine(&ctx.manifest, name, &key)?;
                let ppl = eval_ppl(&engine, &corpus, ctx.manifest.eval_seq, windows)?;
                let probe =
                    probe_suite(&engine, &corpus, ctx.manifest.eval_seq, probe_windows, 64)?;
                let acc = tasks::average_accuracy(&probe);
                row.push(format!("{ppl:.2} ({acc:.2})"));
                let per_task: Vec<_> = probe
                    .iter()
                    .map(|p| obj(vec![("task", s(p.task)), ("acc", num(p.accuracy()))]))
                    .collect();
                json_rows.push(obj(vec![
                    ("rho", num(rho)),
                    ("method", s(m)),
                    ("ppl", num(ppl)),
                    ("avg_acc", num(acc)),
                    ("tasks", arr(per_task)),
                ]));
            }
            rows.push(row);
        }
        print_table(&["rho", "Baseline", "SVD", "PaLU", "RAP"], &rows);
        json_models.push(obj(vec![
            ("model", s(name.clone())),
            ("baseline_ppl", num(base_ppl)),
            ("baseline_acc", num(base_acc)),
            ("rows", arr(json_rows)),
        ]));
    }
    ctx.write_json("accuracy", &arr(json_models))
}

/// Figs. 9/10: long-context suite vs rho + the parameter-matched
/// comparison (RAP at the rho whose params match PaLU@30%).
pub fn longbench(ctx: &ExpContext) -> Result<()> {
    let corpus = ctx.manifest.eval_corpus()?;
    let cases = if ctx.quick { 2 } else { 6 };
    let ctx_len = if ctx.quick { 192 } else { 320 };
    let mut json_models = Vec::new();
    for (name, entry) in &ctx.manifest.models {
        println!("\nLong-context suite ({name}, ctx={ctx_len}): avg accuracy vs rho (Fig. 9):");
        let mut rows = Vec::new();
        let mut json_rows = Vec::new();
        let mut keys = vec![("baseline".to_string(), "baseline_r00".to_string())];
        for (tag, _) in RATIO_KEYS {
            for m in ["svd", "palu", "rap"] {
                keys.push((format!("{m}@{tag}"), format!("{m}_{tag}")));
            }
        }
        for (label, key) in keys {
            let Some(_) = entry.variants.get(&key) else { continue };
            let engine = load_engine(&ctx.manifest, name, &key)?;
            let scores = longctx_suite(&engine, &corpus, ctx_len, cases, 42)?;
            let avg = longctx::average_accuracy(&scores);
            rows.push(vec![label.clone(), format!("{avg:.3}")]);
            let per_task: Vec<_> = scores
                .iter()
                .map(|sc| obj(vec![("task", s(sc.task)), ("acc", num(sc.accuracy()))]))
                .collect();
            json_rows.push(obj(vec![
                ("variant", s(key.clone())),
                ("avg", num(avg)),
                ("tasks", arr(per_task)),
            ]));
        }
        print_table(&["variant", "avg accuracy"], &rows);
        json_models.push(obj(vec![("model", s(name.clone())), ("rows", arr(json_rows))]));
        if ctx.quick {
            break; // one model is enough for the quick pass
        }
    }
    ctx.write_json("longbench", &arr(json_models))
}

/// Fig. 12: 4-bit KV quantization stacked on each method.
pub fn quant(ctx: &ExpContext) -> Result<()> {
    let corpus = ctx.manifest.eval_corpus()?;
    let windows = if ctx.quick { 2 } else { 6 };
    let name = "tinyllama";
    let entry = ctx.manifest.model(name)?;
    println!("\nFig. 12 ({name}): PPL with int4 KV cache (f32 PPL in parens):");
    let mut rows = Vec::new();
    let mut json_rows = Vec::new();
    let mut keys = vec!["baseline_r00".to_string()];
    for (tag, _) in RATIO_KEYS {
        keys.push(format!("rap_{tag}"));
    }
    for key in keys {
        if !entry.variants.contains_key(&key) {
            continue;
        }
        let engine = load_engine(&ctx.manifest, name, &key)?;
        let f32_ppl = eval_ppl(&engine, &corpus, ctx.manifest.eval_seq, windows)?;
        let q_ppl = eval_ppl_quantized(&engine, &corpus, ctx.manifest.eval_seq, windows)?;
        rows.push(vec![
            key.clone(),
            format!("{q_ppl:.3}"),
            format!("({f32_ppl:.3})"),
            format!("+{:.2}%", 100.0 * (q_ppl / f32_ppl - 1.0)),
        ]);
        json_rows.push(obj(vec![
            ("variant", s(key.clone())),
            ("ppl_int4", num(q_ppl)),
            ("ppl_f32", num(f32_ppl)),
        ]));
    }
    print_table(&["variant", "int4 PPL", "f32 PPL", "delta"], &rows);
    println!("(paper: 4-bit KV on top of RAP stays near baseline — orthogonality)");
    ctx.write_json("quant", &arr(json_rows))
}
