//! Fig. 16 + Tables 8/11: non-contiguous RoPE kernel microbenchmark.
//!
//! Two levels:
//! 1. **Compiled graphs** (the paper's comparison): the AOT-exported rope
//!    HLOs — contiguous baseline, materialising gather ("PyTorch"), and the
//!    fused Pallas kernel — timed through PJRT across (batch, seq, rho).
//! 2. **Rust hot path**: `rope::apply_gather` (allocating) vs
//!    `RopeTable::apply_fused` (zero-allocation), the L3-side analog.

use std::collections::BTreeMap;
use std::time::Duration;

use anyhow::Result;

use crate::experiments::{print_table, ExpContext};
use crate::rope::{apply_gather, RopeTable};
use crate::runtime::PjrtContext;
use crate::util::json::{arr, num, obj, s};
use crate::util::rng::Rng;
use crate::util::stats::{bench, black_box};

pub fn rope_kernel(ctx: &ExpContext) -> Result<()> {
    let compiled = compiled_kernels(ctx)?;
    let native = native_hot_path(ctx)?;
    ctx.write_json(
        "rope_kernel",
        &obj(vec![("compiled", compiled), ("native", native)]),
    )
}

fn compiled_kernels(ctx: &ExpContext) -> Result<crate::util::json::Value> {
    let pctx = PjrtContext::cpu()?;
    let mut rng = Rng::new(7);
    let (warm, budget) = if ctx.quick {
        (Duration::from_millis(20), Duration::from_millis(150))
    } else {
        (Duration::from_millis(100), Duration::from_millis(600))
    };

    // Group catalog entries by (batch, seq, ratio).
    let mut groups: BTreeMap<(usize, usize, u32), BTreeMap<String, &crate::manifest::RopeBenchEntry>> =
        BTreeMap::new();
    for e in &ctx.manifest.rope_bench {
        groups
            .entry((e.batch, e.seq, (e.ratio * 100.0) as u32))
            .or_default()
            .insert(e.impl_name.clone(), e);
    }
    // Baselines: ratio==0 contiguous entries, per (batch, seq).
    let mut base_ms: BTreeMap<(usize, usize), f64> = BTreeMap::new();

    println!("\nRoPE kernel microbench (compiled graphs; speedup vs contiguous baseline):");
    let mut rows = Vec::new();
    let mut json_rows = Vec::new();
    let shapes: Vec<(usize, usize)> = if ctx.quick {
        vec![(1, 512)]
    } else {
        vec![(1, 1), (1, 128), (1, 512), (1, 2048), (2, 512), (2, 2048), (4, 512), (4, 2048)]
    };
    let ratios: &[u32] = if ctx.quick { &[30] } else { &[10, 20, 30, 40, 50] };

    let mut time_graph = |path: &str, b: usize, s_len: usize, m: usize| -> Result<f64> {
        let exe = pctx.compile_file(&ctx.manifest.root.join(path))?;
        let h = 8usize; // matches the export config (tinyllama heads)
        let n = b * h * s_len * 2 * m;
        let x: Vec<f32> = (0..n).map(|_| rng.normal_f32()).collect();
        let pos: Vec<i32> = (0..s_len as i32).collect();
        let device = pctx.client.devices().into_iter().next().unwrap();
        let xb = pctx
            .client
            .buffer_from_host_buffer(&x, &[b, h, s_len, 2 * m], Some(&device))
            .map_err(|e| anyhow::anyhow!("{e:?}"))?;
        let pb = pctx
            .client
            .buffer_from_host_buffer(&pos, &[s_len], Some(&device))
            .map_err(|e| anyhow::anyhow!("{e:?}"))?;
        let st = bench(path, warm, budget, || {
            let _ = exe.execute_b(&[&xb, &pb]).unwrap();
        });
        Ok(st.mean_ms())
    };

    for (b, s_len) in &shapes {
        // contiguous baseline for this shape
        let Some(base_entry) = groups
            .get(&(*b, *s_len, 0))
            .and_then(|g| g.get("contig"))
        else {
            continue;
        };
        // contiguous uses full head_dim: m recorded in entry.
        let bms = time_graph(&base_entry.path, *b, *s_len, base_entry.m / 1)?;
        base_ms.insert((*b, *s_len), bms);
        for &r in ratios {
            let Some(g) = groups.get(&(*b, *s_len, r)) else { continue };
            let (Some(f), Some(ga)) = (g.get("fused"), g.get("gather")) else { continue };
            let f_ms = time_graph(&f.path, *b, *s_len, f.m)?;
            let g_ms = time_graph(&ga.path, *b, *s_len, ga.m)?;
            rows.push(vec![
                format!("b={b} S={s_len}"),
                format!("{r}%"),
                format!("{bms:.3} ms"),
                format!("{:.2}x", bms / g_ms),
                format!("{:.2}x", bms / f_ms),
            ]);
            json_rows.push(obj(vec![
                ("batch", num(*b as f64)),
                ("seq", num(*s_len as f64)),
                ("rho", num(r as f64 / 100.0)),
                ("baseline_ms", num(bms)),
                ("gather_speedup", num(bms / g_ms)),
                ("fused_speedup", num(bms / f_ms)),
            ]));
        }
    }
    print_table(
        &["shape", "rho", "contig", "gather ('PyTorch')", "fused (Pallas)"],
        &rows,
    );
    println!("(paper Table 11: fused > 1x everywhere; gather can dip below 1x at small shapes)");
    Ok(arr(json_rows))
}

fn native_hot_path(ctx: &ExpContext) -> Result<crate::util::json::Value> {
    let mut rng = Rng::new(9);
    let head_dim = 128usize;
    let h = 8usize;
    println!("\nRust-native RoPE hot path (per-call, head-batch of {h}):");
    let mut rows = Vec::new();
    let mut json_rows = Vec::new();
    let (warm, budget) = if ctx.quick {
        (Duration::from_millis(10), Duration::from_millis(80))
    } else {
        (Duration::from_millis(50), Duration::from_millis(300))
    };
    for rho in [0.1f64, 0.3, 0.5] {
        let m = (((1.0 - rho) * (head_dim / 2) as f64).round()) as usize;
        let idx: Vec<Vec<usize>> = (0..h)
            .map(|_| rng.choose_distinct(head_dim / 2, m))
            .collect();
        let table = RopeTable::new(&idx, head_dim, 10_000.0);
        let mut x: Vec<Vec<f32>> = (0..h)
            .map(|_| (0..2 * m).map(|_| rng.normal_f32()).collect())
            .collect();
        let st_fused = bench("fused", warm, budget, || {
            for (hd, row) in x.iter_mut().enumerate() {
                table.apply_fused(hd, row, black_box(1234));
            }
        });
        let st_gather = bench("gather", warm, budget, || {
            for (hd, row) in x.iter_mut().enumerate() {
                apply_gather(row, black_box(1234), &idx[hd], head_dim, 10_000.0);
            }
        });
        rows.push(vec![
            format!("{:.0}%", rho * 100.0),
            format!("{:.2} us", st_gather.mean_us()),
            format!("{:.2} us", st_fused.mean_us()),
            format!("{:.2}x", st_gather.mean_ns / st_fused.mean_ns),
        ]);
        json_rows.push(obj(vec![
            ("rho", num(rho)),
            ("gather_us", num(st_gather.mean_us())),
            ("fused_us", num(st_fused.mean_us())),
            ("speedup", num(st_gather.mean_ns / st_fused.mean_ns)),
        ]));
    }
    print_table(&["rho", "gather", "fused", "speedup"], &rows);
    let _ = s("");
    Ok(arr(json_rows))
}
