//! Shared plumbing for the `cargo bench` targets (harness = false; the
//! in-tree `util::stats` harness replaces criterion in this offline
//! environment).  Each bench prints one line per case and appends to
//! `results/bench_<name>.json`.

use std::time::Duration;

use crate::util::json::{arr, obj, num, s, Value};
use crate::util::stats::BenchStats;

pub struct BenchReport {
    name: String,
    entries: Vec<Value>,
}

impl BenchReport {
    pub fn new(name: &str) -> BenchReport {
        println!("== bench: {name} ==");
        BenchReport {
            name: name.to_string(),
            entries: Vec::new(),
        }
    }

    pub fn record(&mut self, st: &BenchStats, extra: Vec<(&str, Value)>) {
        println!("{}", st.report());
        let mut fields = vec![
            ("case", s(st.name.clone())),
            ("mean_us", num(st.mean_ns / 1e3)),
            ("p50_us", num(st.p50_ns / 1e3)),
            ("p99_us", num(st.p99_ns / 1e3)),
            ("iters", num(st.iters as f64)),
        ];
        fields.extend(extra);
        self.entries.push(obj(fields));
    }

    pub fn finish(self) {
        let _ = std::fs::create_dir_all("results");
        let path = format!("results/bench_{}.json", self.name);
        let _ = std::fs::write(&path, arr(self.entries).to_string_pretty());
        println!("-> {path}");
    }
}

/// Warmup/budget presets: `RAP_BENCH_FAST=1` shrinks everything (CI).
pub fn budgets() -> (Duration, Duration) {
    if std::env::var("RAP_BENCH_FAST").is_ok() {
        (Duration::from_millis(20), Duration::from_millis(120))
    } else {
        (Duration::from_millis(150), Duration::from_millis(800))
    }
}
