//! Experiment harness: one runner per paper table/figure (see DESIGN.md
//! per-experiment index).  Each runner prints a markdown table mirroring
//! the paper's rows and writes machine-readable JSON into `results/`.

pub mod accuracy;
pub mod bench_support;
pub mod costs;
pub mod kd;
pub mod latency;
pub mod quality_ablation;
pub mod rope_kernel;
pub mod serving;

use std::path::PathBuf;

use anyhow::Result;

use crate::manifest::Manifest;
use crate::util::json::Value;

pub struct ExpContext {
    pub manifest: Manifest,
    pub results_dir: PathBuf,
    /// Reduced repetitions / case counts for CI-speed runs.
    pub quick: bool,
}

impl ExpContext {
    pub fn new(quick: bool) -> Result<ExpContext> {
        let manifest = Manifest::load_default()?;
        let results_dir = PathBuf::from("results");
        std::fs::create_dir_all(&results_dir)?;
        Ok(ExpContext {
            manifest,
            results_dir,
            quick,
        })
    }

    pub fn write_json(&self, name: &str, value: &Value) -> Result<()> {
        let path = self.results_dir.join(format!("{name}.json"));
        std::fs::write(&path, value.to_string_pretty())?;
        println!("  -> {}", path.display());
        Ok(())
    }
}

/// All experiment names, in a sensible execution order.
pub const ALL: [&str; 14] = [
    "table2",
    "params",
    "flops",
    "fig4",
    "accuracy",
    "longbench",
    "quant",
    "ablation",
    "retention-recall",
    "kd",
    "rope-kernel",
    "latency",
    "e2e",
    "table3",
];

pub fn run(ctx: &ExpContext, name: &str) -> Result<()> {
    println!("\n===== experiment: {name} =====");
    match name {
        "table2" => costs::table2(ctx),
        "params" => costs::params(ctx),
        "flops" => costs::flops(ctx),
        "fig4" => accuracy::fig4_layer_sensitivity(ctx),
        "accuracy" => accuracy::accuracy_sweep(ctx),
        "longbench" => accuracy::longbench(ctx),
        "quant" => accuracy::quant(ctx),
        "ablation" => quality_ablation::strategy_ablation(ctx),
        "retention-recall" => quality_ablation::retention_recall(ctx),
        "kd" => kd::kd_ablation(ctx),
        "rope-kernel" => rope_kernel::rope_kernel(ctx),
        "latency" => latency::latency(ctx),
        "e2e" => serving::e2e(ctx),
        "table3" => costs::table3(ctx),
        other => anyhow::bail!("unknown experiment {other:?} (have {ALL:?})"),
    }
}

pub fn run_all(ctx: &ExpContext) -> Result<()> {
    for name in ALL {
        run(ctx, name)?;
    }
    Ok(())
}

/// Markdown table helper.
pub fn print_table(headers: &[&str], rows: &[Vec<String>]) {
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(cell.len());
            }
        }
    }
    let fmt_row = |cells: &[String]| {
        let parts: Vec<String> = cells
            .iter()
            .enumerate()
            .map(|(i, c)| format!("{:<w$}", c, w = widths.get(i).copied().unwrap_or(4)))
            .collect();
        format!("| {} |", parts.join(" | "))
    };
    println!(
        "{}",
        fmt_row(&headers.iter().map(|s| s.to_string()).collect::<Vec<_>>())
    );
    println!(
        "|{}|",
        widths
            .iter()
            .map(|w| "-".repeat(w + 2))
            .collect::<Vec<_>>()
            .join("|")
    );
    for row in rows {
        println!("{}", fmt_row(row));
    }
}

pub fn pct(x: f64) -> String {
    format!("{:.1}%", 100.0 * x)
}
