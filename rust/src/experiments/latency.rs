//! Latency experiments — Figs. 7/11/19/25 + Table 16 (attention-path
//! prefill/decode) via two harnesses:
//!
//! 1. PJRT end-to-end: time the exported prefill/decode executables per
//!    variant (the production path, available at rho in {10,30,50}).
//! 2. Rust engine, attention-isolated: dense rho sweep measuring just the
//!    per-layer attention work (projections + rope + scores + AV + output)
//!    at several KV lengths — the "attention latency" the paper plots.

use std::time::Duration;

use anyhow::Result;

use crate::experiments::{print_table, ExpContext};
use crate::model::load_engine;
use crate::runtime::{PjrtContext, PjrtEngine};
use crate::util::json::{arr, num, obj, s};
use crate::util::stats::{bench, bench_with_samples};

pub fn latency(ctx: &ExpContext) -> Result<()> {
    let pjrt = pjrt_latency(ctx)?;
    let engine = engine_attention_latency(ctx)?;
    ctx.write_json(
        "latency",
        &obj(vec![("pjrt", pjrt), ("engine_attention", engine)]),
    )
}

/// Harness 1: PJRT prefill + decode latency relative to baseline.
fn pjrt_latency(ctx: &ExpContext) -> Result<crate::util::json::Value> {
    let pctx = PjrtContext::cpu()?;
    let corpus = ctx.manifest.eval_corpus()?;
    let (warm, budget) = if ctx.quick {
        (Duration::from_millis(50), Duration::from_millis(300))
    } else {
        (Duration::from_millis(200), Duration::from_millis(1200))
    };
    let mut json_models = Vec::new();
    for (name, entry) in &ctx.manifest.models {
        println!("\nPJRT latency ({name}) — prefill(128) and decode(b=1) vs baseline:");
        let mut rows = Vec::new();
        let mut json_rows = Vec::new();
        let mut base_prefill = 0.0f64;
        let mut base_decode = 0.0f64;
        let mut keys: Vec<String> = vec!["baseline_r00".into()];
        for rho in [10usize, 30, 50] {
            for m in ["svd", "palu", "rap"] {
                keys.push(format!("{m}_r{rho}"));
            }
        }
        for key in keys {
            if !entry.hlo.contains_key(&key) {
                continue;
            }
            let engine = PjrtEngine::load(&pctx, &ctx.manifest, name, &key)?;
            // prefill at the 128 bucket
            let tokens: Vec<i32> = corpus[..128].iter().map(|&b| b as i32).collect();
            let st_p = bench(&format!("{key}/prefill128"), warm, budget, || {
                let _ = engine.prefill(&pctx, "prefill128", &tokens, 1).unwrap();
            });
            // decode at a mid-length context
            let mut caches = engine.empty_caches(1)?;
            let fill = engine.s_max / 2;
            // quick fill: decode a few tokens to a representative position
            for (i, &b) in corpus[..8].iter().enumerate() {
                caches = engine
                    .decode(&pctx, 1, &[b as i32], &[i as i32], &caches)?
                    .caches;
            }
            let st_d = bench(&format!("{key}/decode"), warm, budget, || {
                let _ = engine
                    .decode(&pctx, 1, &[65], &[fill as i32], &caches)
                    .unwrap();
            });
            if key == "baseline_r00" {
                base_prefill = st_p.mean_ns;
                base_decode = st_d.mean_ns;
            }
            rows.push(vec![
                key.clone(),
                format!("{:.2} ms", st_p.mean_ms()),
                format!("{:.0}%", 100.0 * st_p.mean_ns / base_prefill),
                format!("{:.2} ms", st_d.mean_ms()),
                format!("{:.0}%", 100.0 * st_d.mean_ns / base_decode),
            ]);
            json_rows.push(obj(vec![
                ("variant", s(key.clone())),
                ("prefill_ms", num(st_p.mean_ms())),
                ("prefill_rel", num(st_p.mean_ns / base_prefill)),
                ("decode_ms", num(st_d.mean_ms())),
                ("decode_rel", num(st_d.mean_ns / base_decode)),
            ]));
        }
        print_table(
            &["variant", "prefill", "rel", "decode/tok", "rel"],
            &rows,
        );
        json_models.push(obj(vec![("model", s(name.clone())), ("rows", arr(json_rows))]));
        if ctx.quick {
            break;
        }
    }
    Ok(arr(json_models))
}

/// Harness 2: Rust-engine decode-step latency across the full rho sweep
/// and several context lengths (Fig. 7/11 shape: the RAP advantage grows
/// with rho and with context for the reconstruction baselines).
fn engine_attention_latency(ctx: &ExpContext) -> Result<crate::util::json::Value> {
    let corpus = ctx.manifest.eval_corpus()?;
    let ctx_lens: &[usize] = if ctx.quick { &[128] } else { &[64, 128, 256, 320] };
    let mut json_models = Vec::new();
    for (name, entry) in &ctx.manifest.models {
        println!("\nEngine decode-step latency ({name}) by context length (us/token):");
        let mut rows = Vec::new();
        let mut json_rows = Vec::new();
        let mut keys: Vec<String> = vec!["baseline_r00".into()];
        for rho in [10usize, 20, 30, 40, 50] {
            for m in ["svd", "palu", "rap"] {
                let k = format!("{m}_r{rho}");
                if entry.variants.contains_key(&k) {
                    keys.push(k);
                }
            }
        }
        let mut base_by_len: Vec<f64> = vec![0.0; ctx_lens.len()];
        for key in keys {
            let engine = load_engine(&ctx.manifest, name, &key)?;
            let mut row = vec![key.clone()];
            let mut lat_json = Vec::new();
            for (li, &cl) in ctx_lens.iter().enumerate() {
                let mut cache = engine.new_cache(cl + 8);
                for (i, &t) in corpus[..cl].iter().enumerate() {
                    engine.step(t, i, &mut cache);
                }
                let mut stats_f = || {
                    engine.step(corpus[cl], cl, &mut cache);
                };
                let st = bench_with_samples(
                    &format!("{key}@{cl}"),
                    Duration::from_millis(10),
                    Duration::from_millis(if ctx.quick { 60 } else { 200 }),
                    400,
                    &mut stats_f,
                );
                if key == "baseline_r00" {
                    base_by_len[li] = st.mean_ns;
                }
                row.push(format!(
                    "{:.0} ({:.0}%)",
                    st.mean_ns / 1e3,
                    100.0 * st.mean_ns / base_by_len[li]
                ));
                lat_json.push(obj(vec![
                    ("ctx", num(cl as f64)),
                    ("us", num(st.mean_ns / 1e3)),
                    ("rel", num(st.mean_ns / base_by_len[li])),
                ]));
            }
            rows.push(row);
            json_rows.push(obj(vec![("variant", s(key.clone())), ("lat", arr(lat_json))]));
        }
        let mut headers = vec!["variant".to_string()];
        headers.extend(ctx_lens.iter().map(|c| format!("ctx {c}")));
        let href: Vec<&str> = headers.iter().map(|h| h.as_str()).collect();
        print_table(&href, &rows);
        json_models.push(obj(vec![("model", s(name.clone())), ("rows", arr(json_rows))]));
        if ctx.quick {
            break;
        }
    }
    Ok(arr(json_models))
}
