//! Cost-model experiments: Table 2 (+ Table 6, App. C), parameter ratios
//! (Fig. 5 / Table 10 / Fig. 24), FLOPs (Fig. 6 / Table 12 / Fig. 23), and
//! the Table 3 comprehensive summary.

use anyhow::Result;

use crate::config::{Method, ModelConfig};
use crate::cost::{
    break_even_rho, head_cost, layer_kv_params, variant_accounting, Granularity,
};
use crate::experiments::{pct, print_table, ExpContext};
use crate::model::load_engine;
use crate::util::json::{arr, num, obj, s};

const METHODS: [Method; 3] = [Method::Svd, Method::Palu, Method::Rap];
const RATIOS: [f64; 5] = [0.10, 0.20, 0.30, 0.40, 0.50];

/// Table 2 + Table 6 + §3 break-even analysis, at the paper's geometry
/// (H=32, D=128) and the single-head worst case.
pub fn table2(ctx: &ExpContext) -> Result<()> {
    let (h, d) = (32usize, 128usize);
    println!("\nTable 2 factors (H={h}, D={d}) — KV / params / FLOPs vs baseline:");
    let base = head_cost(Method::Baseline, h, d, 1, 1.0);
    let mut rows = Vec::new();
    let mut json_rows = Vec::new();
    for rho in RATIOS {
        let r = 1.0 - rho;
        for m in METHODS {
            let c = head_cost(m, h, d, 1, r);
            rows.push(vec![
                format!("{:.0}%", rho * 100.0),
                m.name().to_string(),
                pct(c.kv_cache / base.kv_cache),
                pct(c.params / base.params),
                pct(c.flops / base.flops),
                format!("{:.3}M", c.flops / 1e6),
            ]);
            json_rows.push(obj(vec![
                ("rho", num(rho)),
                ("method", s(m.name())),
                ("kv", num(c.kv_cache / base.kv_cache)),
                ("params", num(c.params / base.params)),
                ("flops", num(c.flops / base.flops)),
                ("flops_m", num(c.flops / 1e6)),
            ]));
        }
    }
    print_table(&["rho", "method", "KV", "params", "FLOPs", "FLOPs(M)"], &rows);
    println!(
        "\nBaseline per-head per-token KV-projection FLOPs: {:.3}M (paper Table 6: 2.097M)",
        base.flops / 1e6
    );
    println!("\n§3 break-even rho (method starts reducing params/FLOPs):");
    let mut rows = Vec::new();
    for hh in [1usize, 8, 32] {
        rows.push(vec![
            format!("H={hh}"),
            pct(break_even_rho(Method::Svd, hh)),
            pct(break_even_rho(Method::Palu, hh)),
            pct(break_even_rho(Method::Rap, hh)),
        ]);
    }
    print_table(&["heads", "SVD", "PaLU", "RAP"], &rows);

    ctx.write_json("table2", &arr(json_rows))
}

/// Fig. 5 / Table 10 / Fig. 24: attention + full-model parameter ratios —
/// analytic at paper scale (with per-head/cross-head bounds) and measured
/// from the shipped tiny-model weights.
pub fn params(ctx: &ExpContext) -> Result<()> {
    let paper = ModelConfig::paper_llama();
    println!("\nAnalytic attention-parameter ratio vs baseline (paper scale, per-head..cross-head):");
    let base: f64 = layer_kv_params(&paper, Method::Baseline, 1.0, Granularity::PerHead);
    let mut rows = Vec::new();
    for rho in RATIOS {
        let r = 1.0 - rho;
        let mut row = vec![format!("{:.0}%", rho * 100.0)];
        for m in METHODS {
            let ph = layer_kv_params(&paper, m, r, Granularity::PerHead) / base;
            let chd = layer_kv_params(&paper, m, r, Granularity::CrossHead) / base;
            row.push(if m == Method::Rap {
                pct(ph)
            } else {
                format!("{}..{}", pct(ph), pct(chd))
            });
        }
        rows.push(row);
    }
    print_table(&["rho", "SVD (K/V only)", "PaLU", "RAP"], &rows);

    let mut json_models = Vec::new();
    for (name, entry) in &ctx.manifest.models {
        println!("\nMeasured ({name}) attention-size and full-model ratios vs baseline:");
        let cfg = &entry.config;
        let base_acc = variant_accounting(cfg, &entry.variants["baseline_r00"].spec, 1);
        let mut rows = Vec::new();
        let mut json_rows = Vec::new();
        for rho in RATIOS {
            let tag = format!("_r{:02}", (rho * 100.0) as usize);
            let mut row = vec![format!("{:.0}%", rho * 100.0)];
            for m in METHODS {
                let key = format!("{}{}", m.name(), tag);
                if let Some(ve) = entry.variants.get(&key) {
                    let acc = variant_accounting(cfg, &ve.spec, 1);
                    row.push(format!(
                        "{} / {}",
                        pct(acc.attn_params / base_acc.attn_params),
                        pct(acc.model_params / base_acc.model_params)
                    ));
                    json_rows.push(obj(vec![
                        ("rho", num(rho)),
                        ("method", s(m.name())),
                        ("attn_ratio", num(acc.attn_params / base_acc.attn_params)),
                        ("model_ratio", num(acc.model_params / base_acc.model_params)),
                        ("kv_ratio", num(acc.kv_per_token / base_acc.kv_per_token)),
                    ]));
                } else {
                    row.push("-".into());
                }
            }
            rows.push(row);
        }
        print_table(&["rho", "SVD attn/model", "PaLU attn/model", "RAP attn/model"], &rows);
        json_models.push(obj(vec![("model", s(name.clone())), ("rows", arr(json_rows))]));
    }
    ctx.write_json("params", &arr(json_models))
}

/// Fig. 6 / Table 6 / Table 12: analytic + engine-measured FLOPs.
pub fn flops(ctx: &ExpContext) -> Result<()> {
    // Analytic at paper scale (Table 6 reproduction).
    let (h, d) = (32usize, 128usize);
    let base = head_cost(Method::Baseline, h, d, 1, 1.0).flops;
    println!("\nTable 6 (analytic, per-head per-token KV-projection FLOPs, M):");
    let mut rows = Vec::new();
    for rho in RATIOS {
        let mut row = vec![format!("{:.0}%", rho * 100.0)];
        for m in METHODS {
            let f = head_cost(m, h, d, 1, 1.0 - rho).flops;
            row.push(format!("{:.3} ({})", f / 1e6, pct(1.0 - f / base)));
        }
        rows.push(row);
    }
    print_table(&["rho", "SVD", "PaLU", "RAP"], &rows);

    // Measured: count actual engine FLOPs for one decode step at a fixed
    // context (attention block only ~= step FLOPs minus MLP/embed, but we
    // report whole-step and attention-estimated numbers).
    let mut json_models = Vec::new();
    for name in ctx.manifest.models.keys() {
        println!("\nMeasured per-token step FLOPs ({name}), context 256:");
        let corpus = ctx.manifest.eval_corpus()?;
        let mut rows = Vec::new();
        let mut json_rows = Vec::new();
        let mut base_flops = 0u64;
        for rho_key in ["baseline_r00", "svd_r30", "palu_r30", "rap_r30"] {
            let Ok(engine) = load_engine(&ctx.manifest, name, rho_key) else {
                continue;
            };
            let s_len = if ctx.quick { 128 } else { 256 };
            let mut cache = engine.new_cache(s_len + 1);
            for (i, &t) in corpus[..s_len].iter().enumerate() {
                engine.step(t, i, &mut cache);
            }
            engine.flops.take();
            engine.step(corpus[s_len], s_len, &mut cache);
            let step = engine.flops.take();
            if rho_key == "baseline_r00" {
                base_flops = step;
            }
            rows.push(vec![
                rho_key.to_string(),
                format!("{:.3}M", step as f64 / 1e6),
                pct(1.0 - step as f64 / base_flops as f64),
            ]);
            json_rows.push(obj(vec![
                ("variant", s(rho_key)),
                ("step_flops", num(step as f64)),
                ("saving", num(1.0 - step as f64 / base_flops as f64)),
            ]));
        }
        print_table(&["variant", "step FLOPs", "saving"], &rows);
        json_models.push(obj(vec![("model", s(name.clone())), ("rows", arr(json_rows))]));
    }
    ctx.write_json("flops", &arr(json_models))
}

/// Table 3: the comprehensive rho=30% comparison.
pub fn table3(ctx: &ExpContext) -> Result<()> {
    let corpus = ctx.manifest.eval_corpus()?;
    let mut json_models = Vec::new();
    for (name, entry) in &ctx.manifest.models {
        let cfg = &entry.config;
        println!("\nTable 3 ({name}, rho=30%) — all metrics relative to baseline:");
        let base_acc = variant_accounting(cfg, &entry.variants["baseline_r00"].spec, 1);
        let base_engine = load_engine(&ctx.manifest, name, "baseline_r00")?;
        let windows = if ctx.quick { 4 } else { 12 };
        let base_ppl =
            crate::eval::eval_ppl(&base_engine, &corpus, ctx.manifest.eval_seq, windows)?;
        let mut rows = vec![vec![
            "baseline".into(),
            "100%".into(),
            "100%".into(),
            "100%".into(),
            "100%".into(),
            format!("{base_ppl:.2}"),
        ]];
        let mut json_rows = Vec::new();
        for m in METHODS {
            let key = format!("{}_r30", m.name());
            let Some(ve) = entry.variants.get(&key) else { continue };
            let acc = variant_accounting(cfg, &ve.spec, 1);
            let engine = load_engine(&ctx.manifest, name, &key)?;
            let ppl =
                crate::eval::eval_ppl(&engine, &corpus, ctx.manifest.eval_seq, windows)?;
            rows.push(vec![
                m.name().to_string(),
                pct(acc.kv_per_token / base_acc.kv_per_token),
                pct(acc.attn_params / base_acc.attn_params),
                pct(acc.attn_flops_per_token / base_acc.attn_flops_per_token),
                pct(acc.model_params / base_acc.model_params),
                format!("{ppl:.2}"),
            ]);
            json_rows.push(obj(vec![
                ("method", s(m.name())),
                ("kv", num(acc.kv_per_token / base_acc.kv_per_token)),
                ("attn_params", num(acc.attn_params / base_acc.attn_params)),
                (
                    "attn_flops",
                    num(acc.attn_flops_per_token / base_acc.attn_flops_per_token),
                ),
                ("model_params", num(acc.model_params / base_acc.model_params)),
                ("ppl", num(ppl)),
                ("baseline_ppl", num(base_ppl)),
            ]));
        }
        print_table(
            &["method", "KV", "attn params", "attn FLOPs", "model params", "PPL"],
            &rows,
        );
        json_models.push(obj(vec![("model", s(name.clone())), ("rows", arr(json_rows))]));
    }
    ctx.write_json("table3", &arr(json_models))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_table6_reference_values() {
        // Regression-lock the analytic numbers printed by table2 against
        // the paper's Table 6 row at rho=30%.
        let base = head_cost(Method::Baseline, 32, 128, 1, 1.0).flops / 1e6;
        assert!((base - 2.097).abs() < 0.001);
        assert!((head_cost(Method::Rap, 32, 128, 1, 0.7).flops / 1e6 - 1.468).abs() < 0.002);
    }
}
