//! Figs. 14/15 + Tables 5/7: KD ablation and recovery curves.
//!
//! The KD training itself ran in the build-time pipeline; its curve logs
//! live under `artifacts/logs/`.  This runner re-measures the with/without
//! PPLs with the Rust engine (independent of the python numbers) and
//! replays the curves.

use anyhow::{Context, Result};

use crate::eval::eval_ppl;
use crate::experiments::{print_table, ExpContext};
use crate::model::load_engine;
use crate::util::json::{self, arr, num, obj, s};

pub fn kd_ablation(ctx: &ExpContext) -> Result<()> {
    let corpus = ctx.manifest.eval_corpus()?;
    let windows = if ctx.quick { 4 } else { 12 };
    let mut json_models = Vec::new();

    for (name, entry) in &ctx.manifest.models {
        println!("\nKD ablation ({name}) — Table 5 analog (PPL):");
        let base = load_engine(&ctx.manifest, name, "baseline_r00")?;
        let base_ppl = eval_ppl(&base, &corpus, ctx.manifest.eval_seq, windows)?;
        let mut rows = Vec::new();
        let mut json_rows = Vec::new();
        for rho in [10usize, 20, 30, 40, 50] {
            let kd_key = format!("rap_r{rho}");
            let raw_key = format!("rap_r{rho}_noKD");
            if !(entry.variants.contains_key(&kd_key) && entry.variants.contains_key(&raw_key)) {
                continue;
            }
            let kd = eval_ppl(
                &load_engine(&ctx.manifest, name, &kd_key)?,
                &corpus,
                ctx.manifest.eval_seq,
                windows,
            )?;
            let raw = eval_ppl(
                &load_engine(&ctx.manifest, name, &raw_key)?,
                &corpus,
                ctx.manifest.eval_seq,
                windows,
            )?;
            rows.push(vec![
                format!("{rho}%"),
                format!("{base_ppl:.3}"),
                format!("{raw:.3}"),
                format!("{kd:.3}"),
            ]);
            json_rows.push(obj(vec![
                ("rho", num(rho as f64 / 100.0)),
                ("baseline", num(base_ppl)),
                ("no_kd", num(raw)),
                ("kd", num(kd)),
            ]));
        }
        print_table(&["rho", "Baseline", "RAP (w/o KD)", "RAP"], &rows);

        // Table 7: PaLU+KD comparison at rho=30%.
        if entry.variants.contains_key("palu_r30_kd") {
            let palu = eval_ppl(
                &load_engine(&ctx.manifest, name, "palu_r30")?,
                &corpus,
                ctx.manifest.eval_seq,
                windows,
            )?;
            let palu_kd = eval_ppl(
                &load_engine(&ctx.manifest, name, "palu_r30_kd")?,
                &corpus,
                ctx.manifest.eval_seq,
                windows,
            )?;
            println!(
                "Table 7 analog: PaLU {palu:.3} -> +KD {palu_kd:.3} (gain {:+.1}%)",
                100.0 * (1.0 - palu_kd / palu)
            );
            json_rows.push(obj(vec![
                ("palu_r30", num(palu)),
                ("palu_r30_kd", num(palu_kd)),
            ]));
        }

        // Fig. 15: replay the recovery curves from the build logs.
        let log_path = ctx.manifest.root.join("logs").join(format!("{name}_logs.json"));
        let mut curves = json::Value::Null;
        if let Ok(text) = std::fs::read_to_string(&log_path) {
            let logs = json::parse(&text)
                .map_err(|e| anyhow::anyhow!("parse {}: {e}", log_path.display()))?;
            if let Some(kd_logs) = logs.get("kd") {
                curves = kd_logs.clone();
                if let Some(r30) = kd_logs.get("rap_r30") {
                    let curve = r30.req("curve").as_arr().context("curve")?;
                    let pts: Vec<String> = curve
                        .iter()
                        .filter_map(|e| {
                            let step = e.get("step")?.as_i64()?;
                            let ppl = e.get("ppl")?.as_f64()?;
                            Some(format!("step {step}: {ppl:.3}"))
                        })
                        .collect();
                    println!("Fig. 15 analog (rap_r30 recovery curve): {}", pts.join(", "));
                }
            }
        }
        json_models.push(obj(vec![
            ("model", s(name.clone())),
            ("rows", arr(json_rows)),
            ("curves", curves),
        ]));
    }
    ctx.write_json("kd", &arr(json_models))
}
