//! Table 17 analog: full-model end-to-end serving through the coordinator
//! (continuous batching, paged KV) on a seeded request trace, per variant.

use anyhow::Result;

use crate::coordinator::{BatcherConfig, Coordinator, CoordinatorConfig};
use crate::experiments::{print_table, ExpContext};
use crate::kvcache::CacheShape;
use crate::runtime::backend::PjrtBackend;
use crate::runtime::{PjrtContext, PjrtEngine};
use crate::util::json::{arr, num, obj, s};
use crate::workload::{generate, WorkloadConfig};

pub fn e2e(ctx: &ExpContext) -> Result<()> {
    let pctx = PjrtContext::cpu()?;
    let corpus = ctx.manifest.eval_corpus()?;
    let wl_cfg = WorkloadConfig {
        n_requests: if ctx.quick { 6 } else { 24 },
        arrival_rate: 50.0,
        prompt_lens: vec![16, 32, 32, 64],
        min_new: 8,
        max_new: if ctx.quick { 16 } else { 32 },
        seed: 42,
    };

    let mut json_models = Vec::new();
    for (name, entry) in &ctx.manifest.models {
        println!("\nE2E serving ({name}) — same trace per variant:");
        let mut rows = Vec::new();
        let mut json_rows = Vec::new();
        let mut base_tps = 0.0f64;
        for key in ["baseline_r00", "svd_r30", "palu_r30", "rap_r30"] {
            if !entry.hlo.contains_key(key) {
                continue;
            }
            let engine = PjrtEngine::load(&pctx, &ctx.manifest, name, key)?;
            let backend = PjrtBackend::new(&pctx, &engine)?;
            let shape = CacheShape::of(&entry.config, &entry.variants[key].spec);
            let mut coord = Coordinator::new(
                backend,
                shape,
                CoordinatorConfig {
                    batcher: BatcherConfig {
                        max_sessions: 4,
                        buckets: engine.decode_batches(),
                        max_queue: 256,
                        ..Default::default()
                    },
                    kv_budget_bytes: 32 << 20,
                },
            );
            for tr in generate(&wl_cfg, &corpus) {
                coord.submit(tr.request);
            }
            coord.run_to_completion()?;
            let m = &coord.metrics;
            if key == "baseline_r00" {
                base_tps = m.throughput_tps();
            }
            rows.push(vec![
                key.to_string(),
                format!("{:.1}", m.throughput_tps()),
                format!("{:.0}%", 100.0 * m.throughput_tps() / base_tps),
                format!("{:.1}", m.ttft.mean()),
                format!("{:.2}", m.decode_per_token.mean()),
                format!("{}", m.peak_kv_blocks),
                format!("{:.2}", m.decode_batch_occupancy.mean()),
            ]);
            json_rows.push(obj(vec![
                ("variant", s(key)),
                ("throughput_tps", num(m.throughput_tps())),
                ("rel_throughput", num(m.throughput_tps() / base_tps)),
                ("ttft_ms", num(m.ttft.mean())),
                ("decode_ms_per_tok", num(m.decode_per_token.mean())),
                ("peak_kv_blocks", num(m.peak_kv_blocks as f64)),
                ("batch_occupancy", num(m.decode_batch_occupancy.mean())),
            ]));
        }
        print_table(
            &["variant", "tok/s", "rel", "ttft ms", "dec ms/tok", "peak KV blk", "occupancy"],
            &rows,
        );
        json_models.push(obj(vec![("model", s(name.clone())), ("rows", arr(json_rows))]));
        if ctx.quick {
            break;
        }
    }
    ctx.write_json("e2e", &arr(json_models))
}
