//! Fig. 13: pruning-strategy ablation — Fisher/Magnitude × Adaptive/Uniform
//! (+ KD) at rho=30%.  Plus the retention-press recall ablation: how many
//! planted needle tokens survive each press at each keep ratio.

use anyhow::Result;

use crate::config::Method;
use crate::eval::eval_ppl;
use crate::experiments::{print_table, ExpContext};
use crate::kvcache::retention::{Press, RetentionSpec};
use crate::kvcache::{CacheShape, PagedKvCache};
use crate::model::synth::synth_engine;
use crate::model::{load_engine, BatchWorkspace, PrefillWorkspace};
use crate::tensor::simd::KernelPath;
use crate::util::json::{arr, num, obj, s};
use crate::workload::{generate_needles, NeedleConfig};

pub fn strategy_ablation(ctx: &ExpContext) -> Result<()> {
    let name = "tinyllama";
    let entry = ctx.manifest.model(name)?;
    let corpus = ctx.manifest.eval_corpus()?;
    let windows = if ctx.quick { 4 } else { 12 };

    // (label, variant key) in paper order: BL, FA+KD, FA, FU, MA, MU.
    let arms = [
        ("BL (baseline)", "baseline_r00"),
        ("FA+KD (Fisher+Adaptive+KD)", "rap_r30"),
        ("FA (Fisher+Adaptive)", "rap_r30_noKD"),
        ("FU (Fisher+Uniform)", "rap_r30_FU"),
        ("MA (Magnitude+Adaptive)", "rap_r30_MA"),
        ("MU (Magnitude+Uniform)", "rap_r30_MU"),
    ];
    println!("\nFig. 13 ({name}): strategy ablation at rho=30%:");
    let mut rows = Vec::new();
    let mut json_rows = Vec::new();
    let mut values = std::collections::BTreeMap::new();
    for (label, key) in arms {
        if !entry.variants.contains_key(key) {
            continue;
        }
        let engine = load_engine(&ctx.manifest, name, key)?;
        let ppl = eval_ppl(&engine, &corpus, ctx.manifest.eval_seq, windows)?;
        rows.push(vec![label.to_string(), format!("{ppl:.3}")]);
        json_rows.push(obj(vec![("arm", s(label)), ("key", s(key)), ("ppl", num(ppl))]));
        values.insert(key, ppl);
    }
    print_table(&["arm", "PPL"], &rows);

    // The paper's two claims, checked programmatically:
    let fisher_beats_magnitude = values.get("rap_r30_noKD").zip(values.get("rap_r30_MA"))
        .map(|(f, m)| f < m)
        .unwrap_or(false);
    let adaptive_beats_uniform = values.get("rap_r30_noKD").zip(values.get("rap_r30_FU"))
        .map(|(a, u)| a < u)
        .unwrap_or(false);
    println!(
        "claims: Fisher<Magnitude: {fisher_beats_magnitude}  Adaptive<Uniform: {adaptive_beats_uniform}"
    );
    ctx.write_json(
        "ablation",
        &obj(vec![
            ("rows", arr(json_rows)),
            ("fisher_beats_magnitude", crate::util::json::Value::Bool(fisher_beats_magnitude)),
            ("adaptive_beats_uniform", crate::util::json::Value::Bool(adaptive_beats_uniform)),
        ]),
    )
}

/// Needle recall per retention press × keep ratio: plant recall tokens at
/// known logical positions, press the cache, and count how many planted
/// positions survive in the session's row map.  Runs on the synthetic
/// engine — no model artifacts needed, fully deterministic under the
/// workload seed.
pub fn retention_recall(ctx: &ExpContext) -> Result<()> {
    let mut engine = synth_engine(Method::Rap, 23);
    engine.set_kernel_path(KernelPath::Scalar);
    let shape = CacheShape::of(&engine.cfg, &engine.spec);
    let total_len = if ctx.quick { 768 } else { 2048 };
    let needles = generate_needles(&NeedleConfig {
        total_len,
        n_needles: 24,
        margin: 64,
        seed: 7,
    });
    let presses = [
        Press::Window,
        Press::L2Norm,
        Press::AttnScore,
        Press::AnchorReservoir,
    ];
    let ratios = [0.25f32, 0.5, 0.75];
    const DECODE_STEPS: usize = 8;

    println!("\nretention recall ({total_len}-token haystack, 24 needles):");
    let mut rows = Vec::new();
    let mut json_rows = Vec::new();
    let mut recall_at = std::collections::BTreeMap::new();
    for press in presses {
        for ratio in ratios {
            let mut kv = PagedKvCache::with_storage(shape, 256 << 20);
            kv.reserve(1, total_len + DECODE_STEPS)?;
            if press == Press::AttnScore {
                kv.set_score_tracking(1, true);
            }
            let mut ws = PrefillWorkspace::new(&engine, total_len + DECODE_STEPS);
            engine.prefill_chunk_paged(1, &needles.prompt, 0, &mut kv, &mut ws, false, false)?;
            // A few decode steps so score-driven presses have attention
            // mass to rank rows by.
            let mut batch = BatchWorkspace::new(&engine, total_len + DECODE_STEPS);
            for i in 0..DECODE_STEPS {
                let tok = (i * 31 % 241) as u8;
                engine.decode_batch_paged(&[(1, tok, total_len + i)], &mut kv, &mut batch, false)?;
            }
            let spec = RetentionSpec { press, ratio };
            let evicted = kv.apply_press(1, &spec, total_len + DECODE_STEPS)?;
            let written = total_len + DECODE_STEPS;
            let survivors: Vec<u32> = match kv.row_positions(1) {
                Some(pv) => pv.to_vec(),
                None => (0..written as u32).collect(),
            };
            let recall = needles.recall(&survivors);
            recall_at.insert((spec.press.name(), (ratio * 100.0) as u32), recall);
            rows.push(vec![
                spec.press.name().to_string(),
                format!("{ratio:.2}"),
                format!("{}", survivors.len()),
                format!("{evicted}"),
                format!("{recall:.3}"),
            ]);
            json_rows.push(obj(vec![
                ("press", s(spec.press.name())),
                ("ratio", num(ratio as f64)),
                ("retained_rows", num(survivors.len() as f64)),
                ("evicted_rows", num(evicted as f64)),
                ("recall", num(recall)),
            ]));
        }
    }
    print_table(&["press", "ratio", "retained", "evicted", "recall"], &rows);

    // The claim the ablation exists to check: a plain recency window
    // forgets mid-context needles, the anchor+reservoir press keeps a
    // ratio-proportional share of them.
    let anchor_vs_window = recall_at
        .get(&("anchor-reservoir", 25))
        .zip(recall_at.get(&("window", 25)))
        .map(|(a, w)| a >= w)
        .unwrap_or(false);
    println!("claims: anchor_reservoir recall >= window recall at ratio 0.25: {anchor_vs_window}");
    ctx.write_json(
        "retention_recall",
        &obj(vec![
            ("haystack_tokens", num(total_len as f64)),
            ("n_needles", num(needles.positions.len() as f64)),
            ("rows", arr(json_rows)),
            (
                "anchor_reservoir_recall_geq_window_at_quarter_ratio",
                crate::util::json::Value::Bool(anchor_vs_window),
            ),
        ]),
    )
}
