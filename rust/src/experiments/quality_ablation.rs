//! Fig. 13: pruning-strategy ablation — Fisher/Magnitude × Adaptive/Uniform
//! (+ KD) at rho=30%.

use anyhow::Result;

use crate::eval::eval_ppl;
use crate::experiments::{print_table, ExpContext};
use crate::model::load_engine;
use crate::util::json::{arr, num, obj, s};

pub fn strategy_ablation(ctx: &ExpContext) -> Result<()> {
    let name = "tinyllama";
    let entry = ctx.manifest.model(name)?;
    let corpus = ctx.manifest.eval_corpus()?;
    let windows = if ctx.quick { 4 } else { 12 };

    // (label, variant key) in paper order: BL, FA+KD, FA, FU, MA, MU.
    let arms = [
        ("BL (baseline)", "baseline_r00"),
        ("FA+KD (Fisher+Adaptive+KD)", "rap_r30"),
        ("FA (Fisher+Adaptive)", "rap_r30_noKD"),
        ("FU (Fisher+Uniform)", "rap_r30_FU"),
        ("MA (Magnitude+Adaptive)", "rap_r30_MA"),
        ("MU (Magnitude+Uniform)", "rap_r30_MU"),
    ];
    println!("\nFig. 13 ({name}): strategy ablation at rho=30%:");
    let mut rows = Vec::new();
    let mut json_rows = Vec::new();
    let mut values = std::collections::BTreeMap::new();
    for (label, key) in arms {
        if !entry.variants.contains_key(key) {
            continue;
        }
        let engine = load_engine(&ctx.manifest, name, key)?;
        let ppl = eval_ppl(&engine, &corpus, ctx.manifest.eval_seq, windows)?;
        rows.push(vec![label.to_string(), format!("{ppl:.3}")]);
        json_rows.push(obj(vec![("arm", s(label)), ("key", s(key)), ("ppl", num(ppl))]));
        values.insert(key, ppl);
    }
    print_table(&["arm", "PPL"], &rows);

    // The paper's two claims, checked programmatically:
    let fisher_beats_magnitude = values.get("rap_r30_noKD").zip(values.get("rap_r30_MA"))
        .map(|(f, m)| f < m)
        .unwrap_or(false);
    let adaptive_beats_uniform = values.get("rap_r30_noKD").zip(values.get("rap_r30_FU"))
        .map(|(a, u)| a < u)
        .unwrap_or(false);
    println!(
        "claims: Fisher<Magnitude: {fisher_beats_magnitude}  Adaptive<Uniform: {adaptive_beats_uniform}"
    );
    ctx.write_json(
        "ablation",
        &obj(vec![
            ("rows", arr(json_rows)),
            ("fisher_beats_magnitude", crate::util::json::Value::Bool(fisher_beats_magnitude)),
            ("adaptive_beats_uniform", crate::util::json::Value::Bool(adaptive_beats_uniform)),
        ]),
    )
}
