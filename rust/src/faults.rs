//! Deterministic fault injection for the serving stack.
//!
//! A [`FaultPlan`] is a seed plus per-site failure rates.  Every injection
//! site derives its own [`FaultInjector`] — an independent splitmix64
//! stream keyed on `(seed, site)` — so whether site A fires never shifts
//! site B's schedule, and a storm is exactly reproducible from its seed.
//!
//! Two consumer layers:
//!
//! * the paged allocator ([`crate::kvcache::PagedKvCache`]) consults an
//!   alloc-site injector *only when a reservation actually needs new
//!   blocks* (zero-deficit fast paths stay untouched, preserving the
//!   zero-alloc decode guarantee) and fails the reservation with an
//!   [`InjectedFault`] — exercising the coordinator's eviction/preemption
//!   paths on demand;
//! * [`crate::coordinator::FaultBackend`] wraps any `Backend` and injects
//!   transient prefill/decode errors (before touching the inner backend,
//!   so a retry is always clean) and seeded slow ticks.
//!
//! Injected failures are distinguishable from genuine exhaustion by
//! downcasting to [`InjectedFault`]: the scheduler retries those instead
//! of, e.g., truncating a lone session that merely hit a planned fault.

use std::fmt;

/// Marker error for a planned, injected failure (vs. genuine exhaustion
/// or a real backend error).  Carried inside `anyhow::Error`; recover it
/// with `err.downcast_ref::<InjectedFault>()`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct InjectedFault {
    /// Which injection site fired (e.g. "alloc", "prefill", "decode").
    pub site: &'static str,
}

impl fmt::Display for InjectedFault {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "injected {} fault", self.site)
    }
}

impl std::error::Error for InjectedFault {}

/// splitmix64 — tiny, seedable, and good enough for Bernoulli draws.
#[derive(Debug, Clone)]
struct FaultRng {
    state: u64,
}

impl FaultRng {
    fn new(seed: u64) -> FaultRng {
        FaultRng { state: seed }
    }

    fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Bernoulli draw with probability `p` (clamped to [0, 1]).
    fn chance(&mut self, p: f32) -> bool {
        if p <= 0.0 {
            return false;
        }
        if p >= 1.0 {
            self.next_u64(); // keep the stream advancing uniformly
            return true;
        }
        // 53-bit mantissa; bias at these rates is far below test noise.
        let u = (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64;
        u < p as f64
    }
}

/// Seeded description of a fault storm: one seed, per-site rates.
/// Rates are probabilities in [0, 1]; 0 disables a site.
#[derive(Debug, Clone)]
pub struct FaultPlan {
    pub seed: u64,
    /// P(an allocation that needs new blocks fails) — allocator site.
    pub alloc_fault_rate: f32,
    /// P(a prefill chunk fails transiently before execution).
    pub prefill_fault_rate: f32,
    /// P(a decode batch fails transiently before execution).
    pub decode_fault_rate: f32,
    /// P(a backend call sleeps `slow_tick_ms` first) — a seeded slow tick.
    pub slow_tick_rate: f32,
    pub slow_tick_ms: u64,
}

impl FaultPlan {
    /// All sites disabled; enable with the builder methods.
    pub fn new(seed: u64) -> FaultPlan {
        FaultPlan {
            seed,
            alloc_fault_rate: 0.0,
            prefill_fault_rate: 0.0,
            decode_fault_rate: 0.0,
            slow_tick_rate: 0.0,
            slow_tick_ms: 0,
        }
    }

    pub fn with_alloc_faults(mut self, rate: f32) -> FaultPlan {
        self.alloc_fault_rate = rate;
        self
    }

    pub fn with_prefill_faults(mut self, rate: f32) -> FaultPlan {
        self.prefill_fault_rate = rate;
        self
    }

    pub fn with_decode_faults(mut self, rate: f32) -> FaultPlan {
        self.decode_fault_rate = rate;
        self
    }

    pub fn with_slow_ticks(mut self, rate: f32, ms: u64) -> FaultPlan {
        self.slow_tick_rate = rate;
        self.slow_tick_ms = ms;
        self
    }

    /// Injector for one named site: an independent stream keyed on
    /// `(seed, site)` so sites never perturb each other's schedules.
    pub fn injector(&self, site: &'static str, rate: f32) -> FaultInjector {
        let mut h = self.seed ^ 0x5AFE_FA17_u64.wrapping_mul(site.len() as u64 + 1);
        for b in site.bytes() {
            h = h.wrapping_mul(0x0100_0000_01B3).wrapping_add(b as u64);
        }
        FaultInjector {
            rng: FaultRng::new(h),
            rate,
            site,
            injected: 0,
        }
    }

    pub fn alloc_injector(&self) -> FaultInjector {
        self.injector("alloc", self.alloc_fault_rate)
    }

    pub fn prefill_injector(&self) -> FaultInjector {
        self.injector("prefill", self.prefill_fault_rate)
    }

    pub fn decode_injector(&self) -> FaultInjector {
        self.injector("decode", self.decode_fault_rate)
    }

    pub fn slow_tick_injector(&self) -> FaultInjector {
        self.injector("slow-tick", self.slow_tick_rate)
    }
}

/// One site's deterministic failure stream (see [`FaultPlan::injector`]).
#[derive(Debug, Clone)]
pub struct FaultInjector {
    rng: FaultRng,
    rate: f32,
    site: &'static str,
    injected: u64,
}

impl FaultInjector {
    /// Draw once: does the fault fire at this call?
    pub fn fires(&mut self) -> bool {
        let hit = self.rng.chance(self.rate);
        if hit {
            self.injected += 1;
        }
        hit
    }

    /// The marker error for this site (attach via `anyhow::Error::new`).
    pub fn fault(&self) -> InjectedFault {
        InjectedFault { site: self.site }
    }

    /// Faults fired so far — storms can assert they actually injected.
    pub fn injected(&self) -> u64 {
        self.injected
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn same_seed_same_schedule() {
        let plan = FaultPlan::new(42).with_alloc_faults(0.3);
        let draw = |mut inj: FaultInjector| -> Vec<bool> { (0..64).map(|_| inj.fires()).collect() };
        assert_eq!(draw(plan.alloc_injector()), draw(plan.alloc_injector()));
        let other = FaultPlan::new(43).with_alloc_faults(0.3);
        assert_ne!(
            draw(plan.alloc_injector()),
            draw(other.alloc_injector()),
            "different seeds diverge within 64 draws"
        );
    }

    #[test]
    fn sites_are_independent_streams() {
        let plan = FaultPlan::new(7)
            .with_alloc_faults(0.5)
            .with_decode_faults(0.5);
        let mut a1 = plan.alloc_injector();
        let mut d = plan.decode_injector();
        // Interleaving decode draws must not shift the alloc schedule.
        let solo: Vec<bool> = {
            let mut a2 = plan.alloc_injector();
            (0..32).map(|_| a2.fires()).collect()
        };
        let interleaved: Vec<bool> = (0..32)
            .map(|_| {
                d.fires();
                a1.fires()
            })
            .collect();
        assert_eq!(solo, interleaved);
    }

    #[test]
    fn rates_clamp_and_count() {
        let plan = FaultPlan::new(1).with_prefill_faults(1.0);
        let mut inj = plan.prefill_injector();
        for _ in 0..10 {
            assert!(inj.fires());
        }
        assert_eq!(inj.injected(), 10);
        let mut off = FaultPlan::new(1).decode_injector();
        assert!(!off.fires(), "rate 0 never fires");
        assert_eq!(off.injected(), 0);
    }

    #[test]
    fn rate_roughly_respected() {
        let plan = FaultPlan::new(1234).with_alloc_faults(0.25);
        let mut inj = plan.alloc_injector();
        let hits = (0..4000).filter(|_| inj.fires()).count();
        assert!((800..1200).contains(&hits), "hits {hits} for p=0.25 over 4000");
    }

    #[test]
    fn injected_fault_downcasts() {
        let plan = FaultPlan::new(3).with_alloc_faults(1.0);
        let inj = plan.alloc_injector();
        let err = anyhow::Error::new(inj.fault());
        assert!(err.downcast_ref::<InjectedFault>().is_some());
        assert_eq!(err.to_string(), "injected alloc fault");
    }
}
