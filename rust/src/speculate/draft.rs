//! Self-drafting: propose continuation tokens from the session's own
//! token stream, no second model.
//!
//! [`NgramDrafter`] is prompt-lookup decoding: a hash-indexed n-gram
//! table over `prompt + generated`, updated in O(1) per observed token.
//! When the current suffix n-gram occurred earlier in the stream, the
//! tokens that followed that occurrence become the draft — on
//! repetitive text (code, structured transcripts, copied spans) the
//! verifier then accepts several of them per step for free.
//!
//! Drafter state is *advisory only*: a wrong draft costs one wasted
//! verify chunk, never a wrong token, because acceptance re-samples
//! every emitted token from the verifier's logits (see
//! [`super::accept`]).  That is what lets a preempted session simply
//! rebuild its drafter from `prompt + generated` on resume.

/// Proposes draft tokens for one session; observed tokens arrive in
/// stream order (prompt first, then each emitted token).
pub trait Drafter {
    /// Feed newly appended stream tokens (incremental; never re-feed).
    fn observe(&mut self, tokens: &[u8]);
    /// Propose up to `k` continuation tokens into `out` (cleared first);
    /// returns the number proposed.  Zero means "no draft this step".
    fn draft(&mut self, out: &mut Vec<u8>, k: usize) -> usize;
    /// Forget everything (session rollback to an empty stream).
    fn reset(&mut self);
}

/// Gram orders indexed, shortest to longest; drafting prefers the
/// longest order with a live prior occurrence (more context, higher
/// acceptance).
const ORDERS: [usize; 2] = [2, 3];

/// Hash-table slots per order (power of two).  Collisions are verified
/// against the actual stream bytes, so a collision only costs a missed
/// draft, never a wrong one.
const TABLE_SLOTS: usize = 1 << 12;

const NONE: u32 = u32::MAX;

/// Prompt/self n-gram drafter: for each indexed order, `table[h]` holds
/// the end index (exclusive) of the most recent occurrence of the gram
/// hashing to `h`, and `cursor` holds the *previous* occurrence of the
/// stream's current suffix gram — captured at observe time, so drafting
/// is O(orders) with no probing.
pub struct NgramDrafter {
    ctx: Vec<u8>,
    /// `tables[oi][h]` = end index of the latest gram of order
    /// `ORDERS[oi]` hashing to `h` (NONE = never seen).
    tables: Vec<Vec<u32>>,
    /// Prior occurrence (end index) of the current suffix gram per
    /// order, i.e. the table value displaced by the latest insert.
    cursor: [u32; ORDERS.len()],
}

fn gram_hash(gram: &[u8]) -> usize {
    // FNV-1a, masked to the table size.
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in gram {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    (h as usize) & (TABLE_SLOTS - 1)
}

impl NgramDrafter {
    /// `capacity` pre-reserves the stream buffer (prompt + max_new keeps
    /// the steady state allocation-free; growth beyond it is amortized).
    pub fn with_capacity(capacity: usize) -> NgramDrafter {
        NgramDrafter {
            ctx: Vec::with_capacity(capacity),
            tables: ORDERS.iter().map(|_| vec![NONE; TABLE_SLOTS]).collect(),
            cursor: [NONE; ORDERS.len()],
        }
    }

    /// Tokens observed so far.
    pub fn len(&self) -> usize {
        self.ctx.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ctx.is_empty()
    }

    fn observe_one(&mut self, t: u8) {
        self.ctx.push(t);
        let n = self.ctx.len();
        for (oi, &g) in ORDERS.iter().enumerate() {
            if n < g {
                self.cursor[oi] = NONE;
                continue;
            }
            let h = gram_hash(&self.ctx[n - g..n]);
            self.cursor[oi] = self.tables[oi][h];
            self.tables[oi][h] = n as u32;
        }
    }
}

impl Drafter for NgramDrafter {
    fn observe(&mut self, tokens: &[u8]) {
        for &t in tokens {
            self.observe_one(t);
        }
    }

    fn draft(&mut self, out: &mut Vec<u8>, k: usize) -> usize {
        out.clear();
        let n = self.ctx.len();
        if k == 0 {
            return 0;
        }
        for oi in (0..ORDERS.len()).rev() {
            let g = ORDERS[oi];
            let e = self.cursor[oi];
            if e == NONE || n < g {
                continue;
            }
            let e = e as usize;
            debug_assert!(e < n, "cursor holds a PRIOR occurrence");
            // Hash collision guard: the candidate must really match the
            // current suffix gram.
            if self.ctx[e - g..e] != self.ctx[n - g..n] {
                continue;
            }
            let take = k.min(n - e);
            out.extend_from_slice(&self.ctx[e..e + take]);
            return take;
        }
        0
    }

    fn reset(&mut self) {
        self.ctx.clear();
        for t in &mut self.tables {
            t.fill(NONE);
        }
        self.cursor = [NONE; ORDERS.len()];
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn drafted(stream: &[u8], k: usize) -> Vec<u8> {
        let mut d = NgramDrafter::with_capacity(stream.len());
        d.observe(stream);
        let mut out = Vec::new();
        d.draft(&mut out, k);
        out
    }

    #[test]
    fn repeated_phrase_is_drafted() {
        // ...a b c d e ... a b  ->  expects c d e next.
        let stream = [1, 2, 3, 4, 5, 9, 9, 1, 2];
        assert_eq!(drafted(&stream, 3), vec![3, 4, 5]);
        assert_eq!(drafted(&stream, 2), vec![3, 4]);
    }

    #[test]
    fn longest_order_wins() {
        // Suffix [7, 1, 2]: the 3-gram occurred earlier followed by 8,
        // while the latest 2-gram [1, 2] occurrence (inside this very
        // suffix) must not shadow it.
        let stream = [7, 1, 2, 8, 0, 7, 1, 2];
        assert_eq!(drafted(&stream, 1), vec![8]);
    }

    #[test]
    fn novel_suffix_drafts_nothing() {
        assert!(drafted(&[1, 2, 3, 4, 5], 4).is_empty());
        assert!(drafted(&[], 4).is_empty());
        assert!(drafted(&[1], 4).is_empty());
    }

    #[test]
    fn draft_never_exceeds_available_continuation() {
        // [5, 6] recurs immediately before the suffix: only the tokens
        // between the prior occurrence and the present exist to copy.
        let stream = [5, 6, 5, 6];
        assert_eq!(drafted(&stream, 8), vec![5, 6]);
    }

    #[test]
    fn incremental_observe_matches_batch_observe() {
        let stream: Vec<u8> = (0..200).map(|i| (i % 23) as u8).collect();
        let mut inc = NgramDrafter::with_capacity(stream.len());
        for &t in &stream {
            inc.observe(&[t]);
        }
        let mut batch = NgramDrafter::with_capacity(stream.len());
        batch.observe(&stream);
        let (mut a, mut b) = (Vec::new(), Vec::new());
        inc.draft(&mut a, 8);
        batch.draft(&mut b, 8);
        assert_eq!(a, b);
        assert!(!a.is_empty(), "periodic stream must draft");
    }

    #[test]
    fn reset_forgets_the_stream() {
        let mut d = NgramDrafter::with_capacity(16);
        d.observe(&[1, 2, 3, 1, 2]);
        let mut out = Vec::new();
        assert!(d.draft(&mut out, 4) > 0);
        d.reset();
        assert_eq!(d.len(), 0);
        assert_eq!(d.draft(&mut out, 4), 0);
        // Rebuilding after reset behaves like a fresh drafter.
        d.observe(&[1, 2, 3, 1, 2]);
        assert!(d.draft(&mut out, 4) > 0);
        assert_eq!(out, vec![3, 1, 2]);
    }
}
