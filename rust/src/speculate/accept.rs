//! Deterministic acceptance of a verified draft.
//!
//! The verify chunk fed `[last_emitted, d_1 .. d_n]` and returned
//! `n + 1` logits rows; row `i` is the model's next-token distribution
//! after consuming the stream through the `i`-th fed token — exactly
//! the logits the non-speculative run would compute one step at a time.
//! Acceptance therefore never trusts the draft: it draws each emitted
//! token from those verifier logits through the request's own seeded
//! [`Sampler`] stream (one draw per emitted token, greedy short-circuits
//! to argmax with zero draws), and the draft only decides how far the
//! single verify call reaches.  The emitted token sequence — and the
//! RNG stream position — is bit-identical to sequential decode by
//! construction, for greedy AND sampled requests; this is the exact
//! per-request-seed contract every prior PR preserved, and the
//! strong-form equivalent of rejection sampling against the verifier
//! (the emitted token *is* the target-distribution sample).
//!
//! The step stops at the first token that (a) finishes the request, or
//! (b) diverges from the fed draft — later fed rows then hold KV for a
//! context that never happened and are rolled back by the scheduler via
//! [`crate::kvcache::PagedKvCache::truncate_rows`].

use crate::coordinator::request::FinishReason;
use crate::coordinator::sampling::Sampler;

/// What one speculative step produced.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StepOutcome {
    /// Tokens emitted (and appended to `generated`) this step; at least
    /// 1, at most `draft.len() + 1` (all drafts accepted + bonus).
    pub emitted: usize,
    /// Leading draft tokens the verifier confirmed.
    pub accepted_draft: usize,
    /// Finish condition hit mid-step, if any.
    pub finish: Option<FinishReason>,
}

/// Run the acceptance loop for one verified draft.
///
/// `logits[i]` must be the verifier's distribution after the `i`-th fed
/// token (`logits.len() == draft.len() + 1`); `pos0` is the logical
/// position the first fed token sat at, so the token emitted from
/// `logits[i]` lands at position `pos0 + i + 1`.  `finish` is consulted
/// after every emitted token (stop sequences, length caps) — sampling
/// halts immediately on a hit, so the RNG stream never advances past
/// the finishing token.
pub fn accept_step(
    draft: &[u8],
    logits: &[Vec<f32>],
    sampler: &mut Sampler,
    generated: &mut Vec<u8>,
    pos0: usize,
    finish: impl Fn(&[u8], usize) -> Option<FinishReason>,
) -> StepOutcome {
    assert_eq!(logits.len(), draft.len() + 1, "one logits row per fed token");
    let mut out = StepOutcome { emitted: 0, accepted_draft: 0, finish: None };
    for (i, lg) in logits.iter().enumerate() {
        let token = sampler.sample(lg) as u8;
        generated.push(token);
        out.emitted += 1;
        out.finish = finish(generated, pos0 + out.emitted);
        if out.finish.is_some() {
            break;
        }
        if i < draft.len() && token == draft[i] {
            // The fed row at pos0 + i + 1 holds this very token: its KV
            // is already correct, so the next logits row stays valid.
            out.accepted_draft += 1;
        } else {
            break;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::sampling::SamplingParams;

    /// One-hot logits naming `t` (greedy sampler emits `t`).
    fn one_hot(t: u8) -> Vec<f32> {
        let mut v = vec![0.0f32; 256];
        v[t as usize] = 1.0;
        v
    }

    fn greedy() -> Sampler {
        Sampler::new(&SamplingParams::greedy())
    }

    #[test]
    fn full_acceptance_emits_bonus_token() {
        let draft = [5u8, 6, 7];
        let logits: Vec<Vec<f32>> = [5u8, 6, 7, 8].iter().map(|&t| one_hot(t)).collect();
        let mut generated = vec![4u8];
        let out = accept_step(&draft, &logits, &mut greedy(), &mut generated, 10, |_, _| None);
        assert_eq!(out, StepOutcome { emitted: 4, accepted_draft: 3, finish: None });
        assert_eq!(generated, vec![4, 5, 6, 7, 8]);
    }

    #[test]
    fn divergence_stops_after_the_corrected_token() {
        // Verifier says 5 then 9; draft said 5 then 6.
        let draft = [5u8, 6, 7];
        let logits: Vec<Vec<f32>> = [5u8, 9, 7, 8].iter().map(|&t| one_hot(t)).collect();
        let mut generated = Vec::new();
        let out = accept_step(&draft, &logits, &mut greedy(), &mut generated, 0, |_, _| None);
        assert_eq!(out, StepOutcome { emitted: 2, accepted_draft: 1, finish: None });
        assert_eq!(generated, vec![5, 9], "token 9 replaces the rejected draft");
    }

    #[test]
    fn immediate_divergence_still_emits_one_token() {
        let draft = [5u8];
        let logits = vec![one_hot(7), one_hot(8)];
        let mut generated = Vec::new();
        let out = accept_step(&draft, &logits, &mut greedy(), &mut generated, 0, |_, _| None);
        assert_eq!(out, StepOutcome { emitted: 1, accepted_draft: 0, finish: None });
        assert_eq!(generated, vec![7]);
    }

    #[test]
    fn finish_mid_step_halts_sampling() {
        let draft = [5u8, 6, 7];
        let logits: Vec<Vec<f32>> = [5u8, 6, 7, 8].iter().map(|&t| one_hot(t)).collect();
        let mut generated = Vec::new();
        // Length cap of 2 generated tokens.
        let out = accept_step(&draft, &logits, &mut greedy(), &mut generated, 0, |g, _| {
            (g.len() >= 2).then_some(FinishReason::Length)
        });
        assert_eq!(
            out,
            StepOutcome { emitted: 2, accepted_draft: 1, finish: Some(FinishReason::Length) }
        );
        assert_eq!(generated, vec![5, 6]);
    }

    #[test]
    fn sampled_stream_matches_sequential_draws() {
        // The acceptance loop must consume exactly one RNG draw per
        // emitted token, in order — the whole bit-identity contract.
        let params = SamplingParams { temperature: 0.9, top_k: 8, top_p: 0.95, seed: 42 };
        let logits: Vec<Vec<f32>> =
            (0..4).map(|i| (0..64).map(|j| ((i * 31 + j * 7) % 13) as f32 * 0.3).collect()).collect();
        let mut seq = Sampler::new(&params);
        let expect: Vec<u8> = logits.iter().map(|lg| seq.sample(lg) as u8).collect();
        // Draft exactly the expected chain so everything is accepted.
        let draft = expect[..3].to_vec();
        let mut spec = Sampler::new(&params);
        let mut generated = Vec::new();
        let out = accept_step(&draft, &logits, &mut spec, &mut generated, 0, |_, _| None);
        assert_eq!(out.emitted, 4);
        assert_eq!(out.accepted_draft, 3);
        assert_eq!(generated, expect);
    }
}
