//! Per-step draft budgeting for batched latent verification.
//!
//! One speculative step feeds `[last_emitted, d_1 .. d_n]` — `n + 1`
//! tokens — through the blocked chunk kernel and may emit up to `n + 1`
//! tokens (`n` accepted drafts plus the bonus token from the final
//! logits row).  [`draft_budget`] computes the largest safe `n` for the
//! coming step; the invariants it protects are exactly the ones the
//! bit-identity propchecks (`tests/speculative.rs`) pin:
//!
//! * never feed a row at or beyond the backend's `s_max`;
//! * never draft more tokens than the request may still emit;
//! * never let a retention press fire *mid-draft*: the non-speculative
//!   run presses between single-token steps, so a step that would cross
//!   the press threshold runs token-by-token instead (the press then
//!   fires at exactly the same logical length in both runs);
//! * never speculate under an [`Press::AttnScore`] press at all — its
//!   keep set ranks rows by decode-fed attention mass, and a verify
//!   chunk's rejected query rows would pollute that stream.

use crate::kvcache::retention::{press_due, Press, RetentionSpec};

/// How far one speculative step may draft, given where the session
/// stands.  Returns 0 when the step must fall back to plain decode.
///
/// * `k` — the request's configured draft length.
/// * `generated` / `max_new` — tokens emitted so far and the cap.
/// * `pos` — the logical position the next token will be fed at.
/// * `s_max` — backend context bound (rows must stay below it).
/// * `retention` — the session's press, with its current physical row
///   count and logical length, when one is active.
pub fn draft_budget(
    k: usize,
    generated: usize,
    max_new: usize,
    pos: usize,
    s_max: usize,
    retention: Option<(&RetentionSpec, usize, usize)>,
) -> usize {
    // A step emits at most n + 1 tokens and writes rows for logical
    // positions pos .. pos + n (all < s_max).
    let mut n = k
        .min(max_new.saturating_sub(generated).saturating_sub(1))
        .min(s_max.saturating_sub(pos).saturating_sub(1));
    if let Some((spec, rows, logical)) = retention {
        if spec.press == Press::AttnScore {
            return 0;
        }
        // rows - budget(logical) grows by at most one per emitted token,
        // so "not due at the window's end" implies not due anywhere
        // inside it; shrink until the whole window is press-free.
        while n > 0 && press_due(spec, rows + n + 1, logical + n + 1) {
            n -= 1;
        }
    }
    n
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvcache::retention::{MIN_TOKENS, SLACK_TOKENS};

    #[test]
    fn caps_at_request_and_context_bounds() {
        assert_eq!(draft_budget(4, 0, 64, 10, 1024, None), 4);
        // Only 3 tokens may still be emitted: draft at most 2.
        assert_eq!(draft_budget(4, 61, 64, 10, 1024, None), 2);
        // One token left: speculation cannot help.
        assert_eq!(draft_budget(4, 63, 64, 10, 1024, None), 0);
        // Rows pos..pos+n must stay below s_max.
        assert_eq!(draft_budget(4, 0, 64, 1021, 1024, None), 2);
        assert_eq!(draft_budget(4, 0, 64, 1023, 1024, None), 0);
        assert_eq!(draft_budget(4, 0, 64, 2048, 1024, None), 0);
    }

    #[test]
    fn attn_score_press_disables_speculation() {
        let spec = RetentionSpec { press: Press::AttnScore, ratio: 0.5 };
        assert_eq!(draft_budget(4, 0, 64, 10, 1024, Some((&spec, 10, 10))), 0);
    }

    #[test]
    fn press_window_is_never_crossed_mid_draft() {
        let spec = RetentionSpec { press: Press::Window, ratio: 0.5 };
        // Far from the press threshold: full draft.
        let rows = MIN_TOKENS;
        assert_eq!(draft_budget(4, 0, 4096, rows, 1 << 20, Some((&spec, rows, rows))), 4);
        // Right at the threshold: a press would fire within any draft
        // window, so the step degrades to plain decode.
        let rows = 2 * (MIN_TOKENS + SLACK_TOKENS);
        assert!(press_due(&spec, rows + 1, rows + 1));
        assert_eq!(draft_budget(4, 0, 4096, rows, 1 << 20, Some((&spec, rows, rows))), 0);
    }
}
