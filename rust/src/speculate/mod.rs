//! Speculative decode over the latent KV cache.
//!
//! RAP keeps attention in latent widths with no reconstruction, so
//! scoring `k` tokens in one forward pass costs barely more than one —
//! the blocked chunk kernel behind `Backend::prefill_chunk` is already a
//! batched multi-token forward.  This module claims that headroom with
//! self-drafting speculative decode, in three pieces:
//!
//! * [`draft`] — [`draft::Drafter`] implementations proposing up to `k`
//!   continuation tokens per step from the session's own stream (prompt
//!   n-gram lookup: zero extra model weights, built incrementally).
//! * [`verify`] — the per-step draft budget: how many drafted tokens a
//!   session may submit for verification this tick without crossing a
//!   finish bound or perturbing a retention press's firing schedule.
//! * [`accept`] — deterministic acceptance: every emitted token is drawn
//!   from the *verifier's* logits through the request's own seeded
//!   [`crate::coordinator::sampling::Sampler`] stream, so the emitted
//!   text is bit-identical to the non-speculative run by construction
//!   (greedy short-circuits to argmax; the draft only decides how many
//!   of those draws one verify call can cover).
//!
//! Rejected draft rows are rolled back with
//! [`crate::kvcache::PagedKvCache::truncate_rows`], returning drained
//! blocks to the pool so the resident footprint after every step equals
//! the non-speculative run's.

pub mod accept;
pub mod draft;
pub mod verify;

/// Largest draft length a request may ask for; bounds both the wire
/// field and the verify chunk scratch.
pub const MAX_DRAFT_K: usize = 32;

/// Draft length used when a spec names a policy without `:k`.
pub const DEFAULT_DRAFT_K: usize = 4;

/// Drafting policy for one session.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DraftPolicy {
    /// Prompt/self n-gram lookup over `prompt + generated`.
    Ngram,
}

impl DraftPolicy {
    /// Parse the wire/env name (`ngram`).
    pub fn parse(name: &str) -> Option<DraftPolicy> {
        match name {
            "ngram" => Some(DraftPolicy::Ngram),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            DraftPolicy::Ngram => "ngram",
        }
    }
}

/// Per-request speculative-decode policy: draft up to `k` tokens per
/// step under `policy`, verify them in one blocked forward call.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SpeculativeSpec {
    pub policy: DraftPolicy,
    /// Max draft tokens per step, in `[1, MAX_DRAFT_K]`.
    pub k: usize,
}

impl SpeculativeSpec {
    /// Parse `"<policy>:<k>"` (e.g. `ngram:4`).  A bare policy name
    /// defaults to [`DEFAULT_DRAFT_K`].
    pub fn parse(s: &str) -> Option<SpeculativeSpec> {
        let (name, k) = match s.split_once(':') {
            Some((n, k)) => (n, k.parse::<usize>().ok()?),
            None => (s, DEFAULT_DRAFT_K),
        };
        if k == 0 || k > MAX_DRAFT_K {
            return None;
        }
        Some(SpeculativeSpec { policy: DraftPolicy::parse(name)?, k })
    }

    /// Default policy from the `RAP_SPECULATIVE` environment variable
    /// (`None` when unset or unparsable — plain one-token decode).
    pub fn from_env() -> Option<SpeculativeSpec> {
        std::env::var("RAP_SPECULATIVE").ok().as_deref().and_then(SpeculativeSpec::parse)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_specs() {
        let s = SpeculativeSpec::parse("ngram:4").unwrap();
        assert_eq!(s.policy, DraftPolicy::Ngram);
        assert_eq!(s.k, 4);
        assert_eq!(SpeculativeSpec::parse("ngram").unwrap().k, DEFAULT_DRAFT_K);
        assert_eq!(SpeculativeSpec::parse("ngram:32").unwrap().k, MAX_DRAFT_K);
        assert!(SpeculativeSpec::parse("ngram:0").is_none());
        assert!(SpeculativeSpec::parse("ngram:33").is_none());
        assert!(SpeculativeSpec::parse("ngram:four").is_none());
        assert!(SpeculativeSpec::parse("medusa:4").is_none());
        assert!(SpeculativeSpec::parse("").is_none());
    }
}
