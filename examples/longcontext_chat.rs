//! Long-context session demo: fill most of the context window through the
//! PJRT runtime, plant a fact early, and check the model can still retrieve
//! it — while reporting the KV-cache bytes each method would hold resident.
//! This is the paper's motivating workload (§1: long-context inference is
//! KV-cache-bound).
//!
//!     cargo run --release --example longcontext_chat -- [variant]

use anyhow::Result;
use rap::kvcache::CacheShape;
use rap::manifest::Manifest;
use rap::model::argmax;
use rap::runtime::{session::Session, PjrtContext, PjrtEngine};

fn main() -> Result<()> {
    let variant = std::env::args().nth(1).unwrap_or_else(|| "rap_r30".into());
    let model = "tinyllama";
    let manifest = Manifest::load_default()?;
    let entry = manifest.model(model)?;
    let ctx = PjrtContext::cpu()?;
    let engine = PjrtEngine::load(&ctx, &manifest, model, &variant)?;
    let shape = CacheShape::of(&entry.config, &entry.variants[&variant].spec);

    // Long prompt: planted fact + corpus filler up to most of s_max.
    let corpus = manifest.eval_corpus()?;
    let fact = b"the zq is k. ";
    let target_len = engine.s_max - 48;
    let mut prompt = fact.to_vec();
    prompt.extend_from_slice(&corpus[..target_len - prompt.len() - 12]);
    prompt.extend_from_slice(b" the zq is ");

    println!(
        "{model}/{variant}: context {} tokens, resident KV = {} KiB ({:.0}% of baseline)",
        prompt.len(),
        prompt.len() * shape.bytes_per_token() / 1024,
        100.0 * entry.variants[&variant].spec.kv_retained(&entry.config),
    );

    let t0 = std::time::Instant::now();
    let mut session = Session::new(&ctx, &engine)?;
    session.prefill(&prompt)?;
    let prefill_s = t0.elapsed().as_secs_f64();
    let answer = argmax(&session.last_logits) as u8;
    println!(
        "prefill {prefill_s:.2}s | needle query \"the zq is\" -> {:?} (planted: 'k')",
        answer as char
    );

    let t0 = std::time::Instant::now();
    let cont = session.generate(24)?;
    println!(
        "continuation at full context ({:.2} ms/token): {:?}",
        t0.elapsed().as_secs_f64() * 1e3 / cont.len().max(1) as f64,
        String::from_utf8_lossy(&cont)
    );
    Ok(())
}
