//! Compression-sweep walkthrough: for each method and ratio, show the
//! KV-cache / parameter / FLOPs accounting (the paper's Table 2 view of
//! *your* model) and measure quality with the pure-Rust engine.
//!
//!     cargo run --release --example compression_sweep -- [model]

use anyhow::Result;
use rap::cost::variant_accounting;
use rap::eval::eval_ppl;
use rap::manifest::Manifest;
use rap::model::load_engine;

fn main() -> Result<()> {
    let model = std::env::args().nth(1).unwrap_or_else(|| "tinyllama".into());
    let manifest = Manifest::load_default()?;
    let entry = manifest.model(&model)?;
    let corpus = manifest.eval_corpus()?;
    let cfg = &entry.config;

    let base_acc = variant_accounting(cfg, &entry.variants["baseline_r00"].spec, 128);
    println!(
        "{model}: d={} L={} heads {}/{} head_dim {}\n",
        cfg.d_model, cfg.n_layers, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    );
    println!(
        "{:<14} {:>7} {:>11} {:>10} {:>8} {:>8}",
        "variant", "KV%", "attn prm%", "flops%", "PPL", "ΔPPL%"
    );

    let mut base_ppl = 0.0;
    for key in ["baseline_r00", "svd_r10", "palu_r10", "rap_r10", "svd_r30", "palu_r30",
                "rap_r30", "svd_r50", "palu_r50", "rap_r50"] {
        let Some(ve) = entry.variants.get(key) else { continue };
        let acc = variant_accounting(cfg, &ve.spec, 128);
        let engine = load_engine(&manifest, &model, key)?;
        let ppl = eval_ppl(&engine, &corpus, manifest.eval_seq, 8)?;
        if key == "baseline_r00" {
            base_ppl = ppl;
        }
        println!(
            "{:<14} {:>6.1}% {:>10.1}% {:>9.1}% {:>8.3} {:>+7.1}%",
            key,
            100.0 * acc.kv_per_token / base_acc.kv_per_token,
            100.0 * acc.attn_params / base_acc.attn_params,
            100.0 * acc.attn_flops_per_token / base_acc.attn_flops_per_token,
            ppl,
            100.0 * (ppl / base_ppl - 1.0),
        );
    }
    println!(
        "\nOnly RAP's attention params/FLOPs track the KV ratio linearly (paper Table 2);\n\
         SVD/PaLU pay for reconstruction matrices and per-step reconstruction FLOPs."
    );
    Ok(())
}
