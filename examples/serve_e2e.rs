//! End-to-end serving driver (the DESIGN.md §End-to-end validation run):
//! starts the JSON-lines TCP server on the RAP-compressed model, fires a
//! seeded Poisson workload at it from client threads, and reports
//! latency/throughput — then repeats with the uncompressed baseline for
//! the side-by-side.
//!
//!     cargo run --release --example serve_e2e
//!
//! All three layers compose here: Pallas RoPE kernels inside the AOT HLO
//! (L1), the JAX-exported prefill/decode graphs (L2), and the rust
//! coordinator + server (L3) — with python nowhere on the request path.

use std::time::Instant;

use anyhow::Result;
use rap::config::Method;
use rap::coordinator::{BatcherConfig, Coordinator, CoordinatorConfig};
use rap::kvcache::CacheShape;
use rap::manifest::Manifest;
use rap::model::backend::RustBackend;
use rap::model::synth::synth_engine;
use rap::runtime::backend::PjrtBackend;
use rap::runtime::{PjrtContext, PjrtEngine};
use rap::server::{client_request, client_request_stream, serve};
use rap::util::json::{num, obj, s};
use rap::util::threadpool::ThreadPool;
use rap::workload::{generate, WorkloadConfig};

fn drive(model: &str, variant: &str, n_requests: usize) -> Result<()> {
    let manifest = Manifest::load_default()?;
    let entry = manifest.model(model)?;
    let shape = CacheShape::of(&entry.config, &entry.variants[variant].spec);
    println!(
        "\n=== {model}/{variant}: KV {:.0}% of baseline, {} bytes/token",
        100.0 * entry.variants[variant].spec.kv_retained(&entry.config),
        shape.bytes_per_token()
    );

    let model_owned = model.to_string();
    let variant_owned = variant.to_string();
    let factory = move || -> Result<Coordinator<PjrtBackend<'static>>> {
        let manifest = Manifest::load_default()?;
        let ctx: &'static PjrtContext = Box::leak(Box::new(PjrtContext::cpu()?));
        let engine: &'static PjrtEngine = Box::leak(Box::new(PjrtEngine::load(
            ctx,
            &manifest,
            &model_owned,
            &variant_owned,
        )?));
        let backend = PjrtBackend::new(ctx, engine)?;
        Ok(Coordinator::new(
            backend,
            shape,
            CoordinatorConfig {
                batcher: BatcherConfig {
                    max_sessions: 4,
                    buckets: engine.decode_batches(),
                    max_queue: 256,
                    ..Default::default()
                },
                kv_budget_bytes: 64 << 20,
            },
        ))
    };
    let handle = serve("127.0.0.1:0", factory, 4)?;
    let addr = handle.addr;
    println!("server on {addr}");

    // Client side: replay a seeded trace from a small client pool.
    let corpus = manifest.eval_corpus()?;
    let wl = generate(
        &WorkloadConfig {
            n_requests,
            arrival_rate: 30.0,
            prompt_lens: vec![16, 32, 32, 64],
            min_new: 8,
            max_new: 24,
            seed: 7,
        },
        &corpus,
    );
    let pool = ThreadPool::new(4);
    let t0 = Instant::now();
    let results = std::sync::Arc::new(std::sync::Mutex::new(Vec::new()));
    for tr in wl {
        let results = std::sync::Arc::clone(&results);
        pool.execute(move || {
            // honour the trace's arrival time
            let delay = tr.at_secs - t0.elapsed().as_secs_f64();
            if delay > 0.0 {
                std::thread::sleep(std::time::Duration::from_secs_f64(delay));
            }
            let prompt = String::from_utf8_lossy(&tr.request.prompt).to_string();
            match client_request(&addr, &prompt, tr.request.max_new) {
                Ok(resp) => {
                    let ttft = resp.get("ttft_ms").and_then(|v| v.as_f64()).unwrap_or(0.0);
                    let dec = resp
                        .get("decode_ms_per_token")
                        .and_then(|v| v.as_f64())
                        .unwrap_or(0.0);
                    let toks = resp.get("tokens").and_then(|v| v.as_f64()).unwrap_or(0.0);
                    results.lock().unwrap().push((ttft, dec, toks));
                }
                Err(e) => eprintln!("client error: {e:#}"),
            }
        });
    }
    pool.wait_idle();
    let wall = t0.elapsed().as_secs_f64();
    let results = results.lock().unwrap();
    let n = results.len().max(1) as f64;
    let total_toks: f64 = results.iter().map(|r| r.2).sum();
    println!(
        "{} responses in {:.2}s | mean ttft {:.1} ms | mean decode {:.2} ms/tok | {:.1} gen tok/s",
        results.len(),
        wall,
        results.iter().map(|r| r.0).sum::<f64>() / n,
        results.iter().map(|r| r.1).sum::<f64>() / n,
        total_toks / wall
    );
    handle.shutdown();
    Ok(())
}

/// No-artifacts fallback: the synthetic RAP model served by the pure-Rust
/// engine decoding straight out of the storage-backed paged KV-cache —
/// same server, scheduler, continuous batcher and client pool as the PJRT
/// path, so the serving stack is demonstrable anywhere.
fn drive_synth(n_requests: usize) -> Result<()> {
    println!("\n=== synthetic rap model (paged-store rust engine) ===");
    let factory = move || -> Result<Coordinator<RustBackend<'static>>> {
        // Engine leaks deliberately: server lifetime == process lifetime.
        let engine: &'static rap::model::Engine =
            Box::leak(Box::new(synth_engine(Method::Rap, 7)));
        let shape = CacheShape::of(&engine.cfg, &engine.spec);
        let backend = RustBackend::new(engine, 256);
        Ok(Coordinator::new(
            backend,
            shape,
            CoordinatorConfig {
                batcher: BatcherConfig {
                    max_sessions: 8,
                    buckets: vec![1, 4, 8],
                    max_queue: 256,
                    ..Default::default()
                },
                kv_budget_bytes: 64 << 20,
            },
        ))
    };
    let handle = serve("127.0.0.1:0", factory, 4)?;
    let addr = handle.addr;
    println!("server on {addr}");

    let corpus: Vec<u8> = (0..4096).map(|i| (i % 251) as u8).collect();
    let wl = generate(
        &WorkloadConfig {
            n_requests,
            arrival_rate: 30.0,
            prompt_lens: vec![16, 32, 32, 64],
            min_new: 8,
            max_new: 24,
            seed: 7,
        },
        &corpus,
    );
    let pool = ThreadPool::new(4);
    let t0 = Instant::now();
    let done = std::sync::Arc::new(std::sync::atomic::AtomicUsize::new(0));
    let toks = std::sync::Arc::new(std::sync::atomic::AtomicUsize::new(0));
    for tr in wl {
        let (done, toks) = (std::sync::Arc::clone(&done), std::sync::Arc::clone(&toks));
        pool.execute(move || {
            let prompt = String::from_utf8_lossy(&tr.request.prompt).to_string();
            match client_request(&addr, &prompt, tr.request.max_new) {
                Ok(resp) => {
                    let n = resp.get("tokens").and_then(|v| v.as_usize()).unwrap_or(0);
                    toks.fetch_add(n, std::sync::atomic::Ordering::SeqCst);
                    done.fetch_add(1, std::sync::atomic::Ordering::SeqCst);
                }
                Err(e) => eprintln!("client error: {e:#}"),
            }
        });
    }
    pool.wait_idle();
    let wall = t0.elapsed().as_secs_f64();
    println!(
        "{} responses in {wall:.2}s | {:.1} gen tok/s through the paged store",
        done.load(std::sync::atomic::Ordering::SeqCst),
        toks.load(std::sync::atomic::Ordering::SeqCst) as f64 / wall,
    );

    // Serving API v2: the same server streams per-token deltas with
    // seeded sampling and stop sequences — the first delta lands at
    // prefill completion, long before the generation finishes.
    let body = obj(vec![
        ("prompt", s("the serving api streams ")),
        ("max_new", num(24.0)),
        ("temperature", num(0.8)),
        ("top_k", num(40.0)),
        ("seed", num(7.0)),
        ("stop", rap::util::json::arr(vec![s("\n\n")])),
    ]);
    let sc = client_request_stream(&addr, &body)?;
    println!(
        "streaming: first delta {:.1} ms, {} deltas, total {:.1} ms, finish_reason={}",
        sc.first_delta_ms,
        sc.deltas.len(),
        sc.total_ms,
        sc.summary
            .get("finish_reason")
            .and_then(|f| f.as_str())
            .unwrap_or("?"),
    );
    handle.shutdown();
    Ok(())
}

fn main() -> Result<()> {
    let n = std::env::args()
        .nth(1)
        .and_then(|v| v.parse().ok())
        .unwrap_or(12);
    if Manifest::load_default().is_err() {
        drive_synth(n)?;
        println!("\n(run `make artifacts` for the PJRT side-by-side)");
        return Ok(());
    }
    drive("tinyllama", "rap_r30", n)?;
    drive("tinyllama", "baseline_r00", n)?;
    println!("\n(RAP serves the same trace with a 30% smaller KV cache and lower decode latency.)");
    Ok(())
}
