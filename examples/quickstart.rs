//! Quickstart: load the RAP-compressed tiny model through the PJRT runtime,
//! prefill a prompt, generate a continuation, and compare the KV-cache
//! footprint against the uncompressed baseline.
//!
//!     cargo run --release --example quickstart
//!
//! (Run `make artifacts` first.)

use anyhow::Result;
use rap::kvcache::CacheShape;
use rap::manifest::Manifest;
use rap::runtime::{session::Session, PjrtContext, PjrtEngine};

fn main() -> Result<()> {
    let manifest = Manifest::load_default()?;
    let ctx = PjrtContext::cpu()?;
    println!("PJRT platform: {}", ctx.client.platform_name());

    let model = "tinyllama";
    for variant in ["baseline_r00", "rap_r30"] {
        let engine = PjrtEngine::load(&ctx, &manifest, model, variant)?;
        println!(
            "\n== {model}/{variant}: graphs {:?}, k_rank {:?}, v_rank {:?}",
            engine.graph_names(),
            engine.k_rank,
            engine.v_rank
        );

        let entry = manifest.model(model)?;
        let spec = &entry.variants[variant].spec;
        let shape = CacheShape::of(&entry.config, spec);
        println!(
            "KV cache: {} bytes/token ({}% of baseline)",
            shape.bytes_per_token(),
            (100.0 * spec.kv_retained(&entry.config)).round()
        );

        let prompt = b"the quick brown fox ";
        let mut session = Session::new(&ctx, &engine)?;
        let t0 = std::time::Instant::now();
        session.prefill(prompt)?;
        let prefill_ms = t0.elapsed().as_secs_f64() * 1e3;
        let t0 = std::time::Instant::now();
        let gen = session.generate(32)?;
        let decode_ms = t0.elapsed().as_secs_f64() * 1e3 / 32.0;
        println!(
            "prefill {prefill_ms:.1} ms, decode {decode_ms:.2} ms/token\ngenerated: {:?}",
            String::from_utf8_lossy(&gen)
        );
    }
    Ok(())
}
